//! The quantization methods compared across §4's tables, as a single
//! enum so every experiment applies them uniformly to a trained model.

use crate::icquant::{IcqConfig, IcqMatrix};
use crate::model::TrainedModel;
use crate::quant::{
    self, clipping, gptq, grouping, mixed_precision, vq, QuantizerKind,
};
use crate::util::tensor::Matrix;
use std::collections::HashMap;

/// A quantization method at a specific operating point.
#[derive(Clone, Copy, Debug)]
pub enum Method {
    /// FP16 reference (weights untouched; 16 bits/weight).
    Fp16,
    /// Vanilla per-row RTN.
    Rtn { bits: u32 },
    /// Grouped RTN (the "Grouping" suppression baseline).
    RtnGroup { bits: u32, group: usize },
    /// OmniQuant-lite: grouped RTN with grid-searched clipping.
    OmniLite { bits: u32, group: usize },
    /// SqueezeLLM-lite: FP16 outliers + sensitivity K-means inliers.
    SqueezeLite { bits: u32, ratio: f64 },
    /// QuIP-lite: incoherence processing + GPTQ adaptive rounding.
    QuipLite { bits: u32 },
    /// AQLM-lite: d-dim vector quantization.
    AqlmLite { bits: u32, dim: usize },
    /// QuIP#-lite / QTIP-lite: incoherence + VQ.
    QuipSharpLite { bits: u32, dim: usize },
    /// ICQuant on RTN.
    IcqRtn { bits: u32, ratio: f64 },
    /// ICQuant on sensitivity K-means (the paper's ICQuant^SK).
    IcqSk { bits: u32, ratio: f64 },
}

impl Method {
    pub fn name(&self) -> String {
        match *self {
            Method::Fp16 => "FP16".into(),
            Method::Rtn { bits } => format!("RTN-{}b", bits),
            Method::RtnGroup { bits, group } => format!("RTN-{}b-g{}", bits, group),
            Method::OmniLite { bits, group } => format!("OmniQuant~-{}b-g{}", bits, group),
            Method::SqueezeLite { bits, ratio } => {
                format!("SqueezeLLM~-{}b-{:.2}%", bits, ratio * 100.0)
            }
            Method::QuipLite { bits } => format!("QuIP~-{}b", bits),
            Method::AqlmLite { bits, dim } => format!("AQLM~-{}b-d{}", bits, dim),
            Method::QuipSharpLite { bits, dim } => format!("QuIP#~-{}b-d{}", bits, dim),
            Method::IcqRtn { bits, ratio } => {
                format!("ICQuant^RTN-{}b-{:.0}%", bits, ratio * 100.0)
            }
            Method::IcqSk { bits, ratio } => {
                format!("ICQuant^SK-{}b-{:.2}%", bits, ratio * 100.0)
            }
        }
    }

    /// Quantize one matrix; returns (reconstruction, avg bits/weight).
    pub fn quantize_matrix(
        &self,
        w: &Matrix,
        sens: Option<&Matrix>,
        seed: u64,
    ) -> (Matrix, f64) {
        match *self {
            Method::Fp16 => {
                let data = w
                    .data
                    .iter()
                    .map(|&x| crate::util::f16::to_f16_precision(x))
                    .collect();
                (Matrix::from_vec(w.rows, w.cols, data), 16.0)
            }
            Method::Rtn { bits } => {
                let q = quant::quantize_per_row(w, None, QuantizerKind::Rtn, bits);
                let b = q.avg_bits_per_weight(QuantizerKind::Rtn);
                (q.dequantize(), b)
            }
            Method::RtnGroup { bits, group } => {
                let q = grouping::quantize_grouped(w, None, QuantizerKind::Rtn, bits, group);
                let b = q.avg_bits_per_weight();
                (q.dequantize(), b)
            }
            Method::OmniLite { bits, group } => {
                let q = clipping::quantize_clipped_grouped(w, bits, group);
                let b = q.avg_bits_per_weight();
                (q.dequantize(), b)
            }
            Method::SqueezeLite { bits, ratio } => {
                let q = mixed_precision::quantize_mixed(
                    w,
                    sens,
                    QuantizerKind::SensitiveKmeans,
                    bits,
                    ratio,
                );
                let b = q.avg_bits_per_weight();
                (q.dequantize(), b)
            }
            Method::QuipLite { bits } => {
                // Diagonal Hessian proxy from sensitivity column means
                // (activations are not exported; documented in DESIGN.md §5).
                let h = diag_hessian(w, sens);
                let rec = gptq::quantize_quip_lite(w, &h, bits, seed);
                (rec, bits as f64 + 32.0 / w.cols as f64)
            }
            Method::AqlmLite { bits, dim } => {
                let q = vq::quantize_vq(w, sens, dim, bits, seed);
                let b = q.avg_bits_per_weight();
                (q.dequantize(), b)
            }
            Method::QuipSharpLite { bits, dim } => {
                vq::quantize_quip_sharp_lite(w, dim, bits, seed)
            }
            Method::IcqRtn { bits, ratio } => {
                let cfg = IcqConfig {
                    bits,
                    outlier_ratio: ratio,
                    gap_bits: 0,
                    quantizer: QuantizerKind::Rtn,
                };
                let q = IcqMatrix::quantize(w, None, &cfg).unwrap();
                let b = q.avg_bits_per_weight();
                (q.dequantize(), b)
            }
            Method::IcqSk { bits, ratio } => {
                let cfg = IcqConfig {
                    bits,
                    outlier_ratio: ratio,
                    gap_bits: 0,
                    quantizer: QuantizerKind::SensitiveKmeans,
                };
                let q = IcqMatrix::quantize(w, sens, &cfg).unwrap();
                let b = q.avg_bits_per_weight();
                (q.dequantize(), b)
            }
        }
    }

    /// Quantize every projection of a trained model. Returns the
    /// replacement map and the parameter-weighted average bits/weight.
    pub fn quantize_model(
        &self,
        model: &TrainedModel,
    ) -> (HashMap<String, Matrix>, f64) {
        let mut replacements = HashMap::new();
        let mut bit_sum = 0.0f64;
        let mut params = 0usize;
        for (i, t) in model.tensors.iter().enumerate() {
            if !t.is_projection() {
                continue;
            }
            let w = t.as_matrix();
            let sens = model.sensitivity_of(&t.name).map(|s| s.as_matrix());
            let (rec, bits) = self.quantize_matrix(&w, sens.as_ref(), 0xC0FFEE ^ i as u64);
            bit_sum += bits * t.numel() as f64;
            params += t.numel();
            replacements.insert(t.name.clone(), rec);
        }
        (replacements, bit_sum / params.max(1) as f64)
    }
}

/// Diagonal Hessian proxy for GPTQ from sensitivity (column means),
/// damped; identity when no sensitivity is available.
pub fn diag_hessian(w: &Matrix, sens: Option<&Matrix>) -> Vec<f64> {
    let d = w.cols;
    let mut h = vec![0.0f64; d * d];
    match sens {
        Some(s) => {
            for c in 0..d {
                let mut acc = 0.0f64;
                for r in 0..s.rows {
                    acc += s.get(r, c) as f64;
                }
                h[c * d + c] = acc / s.rows as f64;
            }
            let mean = (0..d).map(|c| h[c * d + c]).sum::<f64>() / d as f64;
            for c in 0..d {
                h[c * d + c] += 0.05 * mean.max(1e-12);
            }
        }
        None => {
            for c in 0..d {
                h[c * d + c] = 1.0;
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synthzoo;

    #[test]
    fn every_method_runs_on_a_matrix() {
        let w = synthzoo::demo_matrix(16, 128, 3);
        let methods = [
            Method::Fp16,
            Method::Rtn { bits: 3 },
            Method::RtnGroup { bits: 3, group: 64 },
            Method::OmniLite { bits: 3, group: 64 },
            Method::SqueezeLite { bits: 3, ratio: 0.05 },
            Method::QuipLite { bits: 3 },
            Method::AqlmLite { bits: 3, dim: 2 },
            Method::QuipSharpLite { bits: 3, dim: 2 },
            Method::IcqRtn { bits: 3, ratio: 0.05 },
            Method::IcqSk { bits: 3, ratio: 0.05 },
        ];
        for m in methods {
            let (rec, bits) = m.quantize_matrix(&w, None, 1);
            assert_eq!((rec.rows, rec.cols), (16, 128), "{}", m.name());
            assert!(rec.data.iter().all(|x| x.is_finite()), "{}", m.name());
            assert!(bits > 0.0 && bits <= 16.0, "{} bits {}", m.name(), bits);
        }
    }

    #[test]
    fn icq_beats_vanilla_at_equal_base_bits() {
        let w = synthzoo::demo_matrix(32, 512, 5);
        let (rtn, _) = Method::Rtn { bits: 3 }.quantize_matrix(&w, None, 1);
        let (icq, icq_bits) =
            Method::IcqRtn { bits: 3, ratio: 0.05 }.quantize_matrix(&w, None, 1);
        assert!(w.mse(&icq) < w.mse(&rtn));
        assert!(icq_bits < 3.5);
    }

    #[test]
    fn fp16_is_nearly_lossless() {
        let w = synthzoo::demo_matrix(8, 64, 7);
        let (rec, bits) = Method::Fp16.quantize_matrix(&w, None, 1);
        assert_eq!(bits, 16.0);
        assert!(w.mse(&rec) < 1e-8);
    }
}

//! Fig 4: index-coding overhead B vs gap width b at γ=5 % — Lemma 1
//! bound, synthetic simulation, and empirical measurement on weights.

use super::print_row;
use crate::icq::{lemma1_bound, optimal_b, simulate_overhead};
use crate::icq::coding::encoded_symbol_count;
use crate::model::{artifacts_dir, TrainedModel};
use crate::quant::mixed_precision::top_k_by_magnitude;
use crate::synthzoo::{family, LayerType};
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let gamma = 0.05;
    let d = 2048;
    let trials = if fast { 100 } else { 400 };

    // Empirical positions: trained projections if available, else zoo.
    let rows: Vec<Vec<usize>> = match TrainedModel::load(&artifacts_dir()) {
        Ok(m) => {
            let mut rows = Vec::new();
            for t in m.projections().into_iter().take(8) {
                let w = t.as_matrix();
                let k = (gamma * w.cols as f64) as usize;
                for r in 0..w.rows {
                    rows.push(top_k_by_magnitude(w.row(r), k));
                }
            }
            rows
        }
        Err(_) => {
            let f = family("llama2-7b").unwrap();
            let w = f.gen_stat_layer(LayerType::QProj, 0);
            let k = (gamma * w.cols as f64) as usize;
            (0..w.rows).map(|r| top_k_by_magnitude(w.row(r), k)).collect()
        }
    };
    let emp_d = if rows.is_empty() { d } else { rows[0].len().max(1) };
    let _ = emp_d;

    println!("γ = 5%:  B (bits/weight) per gap width b");
    let widths = [4usize, 12, 12, 12];
    print_row(
        &["b".into(), "Lemma 1".into(), "synthetic".into(), "empirical".into()],
        &widths,
    );
    for b in 3..=10u32 {
        let bound = lemma1_bound(gamma, b);
        let sim = simulate_overhead(d, gamma, b, trials, 42);
        // Empirical over the model rows (re-derive d per row).
        let (mut bits, mut weights) = (0usize, 0usize);
        for pos in &rows {
            // Row width: recover from the trained model's projection cols
            // is not retained here; positions were computed per-row with
            // the row's true width, so track via stored max+1 ≈ width.
            // We instead re-measure with the actual storage accounting:
            bits += encoded_symbol_count(pos, b) * b as usize;
            weights += (pos.len() as f64 / gamma).round() as usize;
        }
        let emp = bits as f64 / weights.max(1) as f64;
        print_row(
            &[
                b.to_string(),
                format!("{:.4}", bound),
                format!("{:.4}", sim),
                format!("{:.4}", emp),
            ],
            &widths,
        );
    }
    println!(
        "\noptimal b at γ=5%: {} (paper: b=6, B ≈ 0.31 bits/weight)",
        optimal_b(gamma)
    );
    Ok(())
}

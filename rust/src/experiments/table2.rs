//! Table 2: the 2-bit regime with *scalar* quantization algorithms —
//! SqueezeLLM-lite, OmniQuant-lite(g64), QuIP-lite, ICQuant^SK-5 % —
//! perplexity on the trained model plus MSE on the zoo scales.

use super::methods::Method;
use super::{print_row, EvalCtx};
use crate::synthzoo::{family, LayerType};
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let methods = [
        Method::Fp16,
        Method::SqueezeLite { bits: 2, ratio: 0.0045 },
        Method::OmniLite { bits: 2, group: 64 },
        Method::QuipLite { bits: 2 },
        Method::IcqSk { bits: 2, ratio: 0.05 },
    ];

    // --- perplexity on the trained model (the paper's ppl column) -------
    let mut ctx = EvalCtx::load(fast)?;
    println!("[trained Llama-mini] test perplexity, 2-bit scalar methods");
    let widths = [26usize, 9, 10];
    print_row(&["method".into(), "bits/w".into(), "ppl".into()], &widths);
    for m in methods {
        let (rep, bits) = m.quantize_model(&ctx.model);
        let ppl = ctx.ppl_with(&rep)?;
        print_row(
            &[m.name(), format!("{:.2}", bits), format!("{:.3}", ppl)],
            &widths,
        );
    }
    println!("\npaper Table 2 (Llama2-7B): FP16 5.47 | SqueezeLLM 10.79 |");
    println!("OmniQuant-g64 9.62 | QuIP n/a | ICQuant^SK-5% 7.21 — ICQuant wins");

    // --- MSE on the zoo scales (7B/13B/70B shapes) -----------------------
    println!("\n[synthzoo] weighted quantization error (MSE), 2-bit methods");
    let fams = if fast {
        vec!["llama2-7b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b"]
    };
    let mut header = vec!["method".to_string()];
    header.extend(fams.iter().map(|f| f.to_string()));
    let w2 = [26usize, 12, 12, 12][..1 + fams.len()].to_vec();
    print_row(&header, &w2);
    for m in methods {
        let mut cells = vec![m.name()];
        for fam in &fams {
            let f = family(fam).unwrap();
            let mut err = 0.0;
            let mut n = 0usize;
            for lt in [LayerType::QProj, LayerType::UpProj] {
                let w = f.gen_layer(lt, 0);
                let s = f.gen_sensitivity(&w, 1);
                let (rec, _) = m.quantize_matrix(&w, Some(&s), 11);
                err += w.sq_err(&rec);
                n += w.numel();
            }
            cells.push(format!("{:.3e}", err / n as f64));
        }
        print_row(&cells, &w2);
    }
    println!("\n(shape check: ICQuant^SK lowest error at every scale)");
    Ok(())
}

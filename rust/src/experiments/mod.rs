//! Experiment harnesses — one per table/figure in the paper (DESIGN.md §5
//! maps each id to the paper artifact it regenerates).
//!
//! Run via `icquant exp <id>` (or `icquant exp all`). Each harness prints
//! paper-style rows; EXPERIMENTS.md records paper-vs-measured.

pub mod methods;

mod fig1;
mod fig2;
mod fig3;
mod fig4;
mod fig5;
mod fig8;
mod fig9;
mod fig10;
mod lemma1;
mod table1;
mod table2;
mod table34;

use anyhow::{bail, Result};

pub struct Experiment {
    pub id: &'static str,
    pub paper_artifact: &'static str,
    pub run: fn(fast: bool) -> Result<()>,
}

/// The registry: every paper table/figure and its harness.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "fig1", paper_artifact: "Fig 1(a,b): outlier range share per layer type", run: fig1::run },
        Experiment { id: "fig2", paper_artifact: "Fig 2: outlier frequency per 256-group", run: fig2::run },
        Experiment { id: "table1", paper_artifact: "Table 1 + Table 5: chi-square rejection rates", run: table1::run },
        Experiment { id: "fig3", paper_artifact: "Fig 3(a,c): 2-bit ICQuant vs 3-bit vanilla RTN", run: fig3::run },
        Experiment { id: "fig4", paper_artifact: "Fig 4: overhead B vs b (bound/synthetic/empirical)", run: fig4::run },
        Experiment { id: "fig5", paper_artifact: "Fig 5(a,b): suppression techniques, ppl + MSE", run: fig5::run },
        Experiment { id: "table2", paper_artifact: "Table 2: 2-bit scalar quantization comparison", run: table2::run },
        Experiment { id: "table3", paper_artifact: "Table 3/4 + 6/7/8: VQ SoTA grid, ppl + zero-shot", run: table34::run },
        Experiment { id: "fig8", paper_artifact: "Fig 8: index storage vs outlier ratio", run: fig8::run },
        Experiment { id: "fig9", paper_artifact: "Fig 9: weight value vs sensitivity", run: fig9::run },
        Experiment { id: "fig10", paper_artifact: "Fig 10/11: incoherence processing examples", run: fig10::run },
        Experiment { id: "lemma1", paper_artifact: "Lemma 1: bound vs measurement", run: lemma1::run },
    ]
}

pub fn run(id: &str, fast: bool) -> Result<()> {
    if id == "all" {
        for e in registry() {
            println!("\n================================================================");
            println!("== {}  ({})", e.id, e.paper_artifact);
            println!("================================================================");
            (e.run)(fast)?;
        }
        return Ok(());
    }
    match registry().into_iter().find(|e| e.id == id) {
        Some(e) => (e.run)(fast),
        None => bail!(
            "unknown experiment '{}'; available: {} (or 'all')",
            id,
            registry().iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
        ),
    }
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

use crate::eval::{load_corpus_tokens, perplexity, weight_literals};
use crate::model::{artifacts_dir, TrainedModel};
use crate::runtime::Engine;

/// Evaluation context for experiments that need the trained model.
pub struct EvalCtx {
    pub model: TrainedModel,
    pub engine: Engine,
    pub test_tokens: Vec<i32>,
    pub windows: usize,
}

impl EvalCtx {
    pub fn load(fast: bool) -> Result<EvalCtx> {
        let dir = artifacts_dir();
        let model = TrainedModel::load(&dir)?;
        model.validate()?;
        let engine = Engine::new(&dir)?;
        let test_tokens = load_corpus_tokens(&dir, "test")?;
        Ok(EvalCtx { model, engine, test_tokens, windows: if fast { 3 } else { 8 } })
    }

    /// Perplexity of the model with `replacements` applied.
    pub fn ppl_with(
        &mut self,
        replacements: &std::collections::HashMap<String, crate::util::tensor::Matrix>,
    ) -> Result<f64> {
        let m = self.model.with_replaced(replacements);
        let w = weight_literals(&m)?;
        perplexity(&mut self.engine, w, &self.test_tokens, self.windows)
    }

    pub fn ppl_fp(&mut self) -> Result<f64> {
        let w = weight_literals(&self.model)?;
        perplexity(&mut self.engine, w, &self.test_tokens, self.windows)
    }
}

/// Simple fixed-width table printer.
pub fn print_row(cells: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cells.iter().zip(widths) {
        line.push_str(&format!("{:<width$}  ", c, width = w));
    }
    println!("{}", line.trim_end());
}

/// An ASCII bar for quick-scan figures.
pub fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    format!("{}{}", "█".repeat(n), "·".repeat(width - n))
}

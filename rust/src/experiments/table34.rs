//! Tables 3/4 (perplexity grid vs vector-quantization SoTA) and
//! Tables 3/6/7/8 (zero-shot accuracy): AQLM-lite, QuIP#-lite, QTIP-lite
//! vs ICQuant^SK at 2/3/4 bits — no fine-tuning anywhere, matching the
//! paper's "without fine-tuning" comparison.

use super::methods::Method;
use super::{print_row, EvalCtx};
use crate::eval::tasks::{generate_tasks, score_task_resident as score_task};
use crate::eval::weight_literals;
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let mut ctx = EvalCtx::load(fast)?;

    let grid: Vec<(u32, Vec<Method>)> = vec![
        (
            4,
            vec![
                Method::AqlmLite { bits: 4, dim: 2 },
                Method::QuipSharpLite { bits: 4, dim: 2 },
                Method::IcqSk { bits: 4, ratio: 0.05 },
            ],
        ),
        (
            3,
            vec![
                Method::AqlmLite { bits: 3, dim: 2 },
                Method::QuipSharpLite { bits: 3, dim: 2 },
                Method::IcqSk { bits: 3, ratio: 0.05 },
            ],
        ),
        (
            2,
            vec![
                Method::AqlmLite { bits: 2, dim: 2 },
                // QTIP-lite: incoherence + higher-dim VQ at the same rate.
                Method::QuipSharpLite { bits: 2, dim: 4 },
                Method::QuipSharpLite { bits: 2, dim: 2 },
                Method::IcqSk { bits: 2, ratio: 0.0825 },
                Method::IcqSk { bits: 2, ratio: 0.05 },
            ],
        ),
    ];

    // Zero-shot tasks over the test split.
    let n_questions = if fast { 12 } else { 30 };
    let tasks = generate_tasks(&ctx.test_tokens, n_questions, 96, 24, 0xA11CE);

    let widths = [26usize, 8, 9, 9, 9, 9, 9];
    let mut header: Vec<String> =
        vec!["method".into(), "bits/w".into(), "ppl↓".into()];
    header.extend(tasks.iter().map(|t| format!("{}↑", t.name)));
    print_row(&header, &widths);

    // FP16 reference row.
    {
        let w = ctx.engine.upload_all(weight_literals(&ctx.model)?)?;
        let fp_ppl = crate::eval::perplexity_resident(
            &mut ctx.engine,
            &w,
            &ctx.test_tokens,
            ctx.windows,
        )?;
        let mut cells = vec!["FP".to_string(), "16".into(), format!("{:.3}", fp_ppl)];
        for t in &tasks {
            let acc = score_task(&mut ctx.engine, &w, t)?;
            cells.push(format!("{:.1}%", acc * 100.0));
        }
        print_row(&cells, &widths);
    }

    for (bits, methods) in grid {
        println!("--- {} bit regime ---", bits);
        for m in methods {
            let (rep, avg_bits) = m.quantize_model(&ctx.model);
            let qm = ctx.model.with_replaced(&rep);
            let w = ctx.engine.upload_all(weight_literals(&qm)?)?;
            let ppl = crate::eval::perplexity_resident(
                &mut ctx.engine,
                &w,
                &ctx.test_tokens,
                ctx.windows,
            )?;
            let mut cells =
                vec![m.name(), format!("{:.2}", avg_bits), format!("{:.3}", ppl)];
            for t in &tasks {
                let acc = score_task(&mut ctx.engine, &w, t)?;
                cells.push(format!("{:.1}%", acc * 100.0));
            }
            print_row(&cells, &widths);
        }
    }

    println!("\npaper Tables 3/4: ICQuant^SK matches or beats un-fine-tuned VQ");
    println!("baselines at every bit-width; at 2 bits the 8.25% variant trades");
    println!("ppl for accuracy exactly as Table 3/4 shows (Llama2) — and the");
    println!("zero-shot gap over VQ baselines is largest in the 2-bit regime.");
    Ok(())
}

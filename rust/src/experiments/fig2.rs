//! Fig 2: frequency of outliers in each group of 256 consecutive weights
//! — visually uniform for q/k/v/up/gate/down, clustered for o_proj.

use super::bar;
use crate::quant::mixed_precision::top_k_by_magnitude;
use crate::stats::group_frequency;
use crate::synthzoo::{family, LayerType};
use anyhow::Result;

pub fn run(_fast: bool) -> Result<()> {
    let f = family("llama2-7b").unwrap();
    for lt in [LayerType::QProj, LayerType::DownProj, LayerType::OProj] {
        let w = f.gen_stat_layer(lt, 1);
        let gamma = 0.0625;
        let k = (w.cols as f64 * gamma) as usize;
        // Aggregate over rows like the paper's figure.
        let mut totals = vec![0usize; w.cols / 256];
        for r in 0..w.rows {
            let pos = top_k_by_magnitude(w.row(r), k);
            for (g, c) in group_frequency(&pos, w.cols, 256).into_iter().enumerate() {
                if g < totals.len() {
                    totals[g] += c;
                }
            }
        }
        let expected = (w.rows * k) as f64 / totals.len() as f64;
        println!(
            "\n[{}] outliers per 256-group (expected {:.0} under uniform):",
            lt.name(),
            expected
        );
        let max = *totals.iter().max().unwrap() as f64;
        for (g, &c) in totals.iter().enumerate() {
            println!("g{:02} {:>6} {}", g, c, bar(c as f64 / max, 40));
        }
        let cv = {
            let mean = totals.iter().sum::<usize>() as f64 / totals.len() as f64;
            let var = totals
                .iter()
                .map(|&c| (c as f64 - mean).powi(2))
                .sum::<f64>()
                / totals.len() as f64;
            var.sqrt() / mean
        };
        println!("coefficient of variation: {:.3}", cv);
    }
    println!("\npaper: near-flat for most layers; o_proj shows clustering");
    Ok(())
}

//! Fig 10/11 (Appendix G.2): incoherence processing before/after — a
//! large rotation benefit only when extreme outliers exist; ≈neutral on
//! Gaussian-like weights. Explains QuIP's small gains outside block 0.

use super::print_row;
use crate::quant::incoherence::Incoherence;
use crate::quant::min_max;
use crate::util::prng::Rng;
use crate::util::tensor::Matrix;
use anyhow::Result;

fn describe(w: &Matrix) -> (f64, f64, f64) {
    let (lo, hi) = min_max(&w.data);
    let std = (w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
        / w.numel() as f64)
        .sqrt();
    ((hi - lo) as f64, std, (hi - lo) as f64 / std)
}

pub fn run(_fast: bool) -> Result<()> {
    let mut rng = Rng::new(17);
    let d = 256;

    // Case 1 (Fig 10, first blocks): extreme outliers present.
    let mut spiky = Matrix::from_vec(
        d,
        d,
        (0..d * d).map(|_| rng.normal() as f32 * 0.02).collect(),
    );
    for _ in 0..20 {
        let r = rng.below(d as u64) as usize;
        let c = rng.below(d as u64) as usize;
        let sign = if rng.bool(0.5) { 1.0 } else { -1.0 };
        spiky.set(r, c, sign * (1.0 + rng.f32() * 2.0));
    }
    // Case 2 (Fig 11-like): already Gaussian.
    let gaussian = Matrix::from_vec(
        d,
        d,
        (0..d * d).map(|_| rng.normal() as f32 * 0.02).collect(),
    );

    let widths = [22usize, 12, 12, 12];
    print_row(
        &["weights".into(), "range".into(), "std".into(), "range/std".into()],
        &widths,
    );
    for (name, w) in [("spiky (early block)", &spiky), ("gaussian (late block)", &gaussian)] {
        let inc = Incoherence::new(d, d, 3);
        let wt = inc.apply(w);
        let (r0, s0, k0) = describe(w);
        let (r1, s1, k1) = describe(&wt);
        print_row(
            &[
                format!("{} before", name),
                format!("{:.4}", r0),
                format!("{:.4}", s0),
                format!("{:.1}", k0),
            ],
            &widths,
        );
        print_row(
            &[
                format!("{} after", name),
                format!("{:.4}", r1),
                format!("{:.4}", s1),
                format!("{:.1}", k1),
            ],
            &widths,
        );
        println!("  range reduction: {:.2}x", r0 / r1);
    }
    println!("\npaper: rotation collapses the spiky range (→ Gaussian) but");
    println!("leaves already-Gaussian weights essentially unchanged.");
    Ok(())
}

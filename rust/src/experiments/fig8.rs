//! Fig 8 (+ Appendix D): index storage cost B across outlier ratios γ for
//! each gap width b, showing the flexible trade-off space.

use super::print_row;
use crate::icq::{lemma1_bound, optimal_b};
use anyhow::Result;

pub fn run(_fast: bool) -> Result<()> {
    let gammas = [0.01, 0.02, 0.03, 0.05, 0.0825, 0.10, 0.125];
    let bs = [4u32, 5, 6, 7, 8];
    let widths = [8usize, 9, 9, 9, 9, 9, 11];
    let mut header = vec!["γ".to_string()];
    header.extend(bs.iter().map(|b| format!("b={}", b)));
    header.push("optimal".into());
    print_row(&header, &widths);
    for &g in &gammas {
        let mut cells = vec![format!("{:.2}%", g * 100.0)];
        for &b in &bs {
            cells.push(format!("{:.4}", lemma1_bound(g, b)));
        }
        let ob = optimal_b(g);
        cells.push(format!("b={} ({:.3})", ob, lemma1_bound(g, ob)));
        print_row(&cells, &widths);
    }
    println!("\npaper: B ≈ 0.31 bits at γ=5%; ≈0.47 at 8.25% — the knob the");
    println!("2-bit ICQuant^SK-8.25% row of Table 3/4 turns.");
    Ok(())
}

//! Fig 9 (Appendix G.1): weight value vs Fisher sensitivity — tails are
//! *less* sensitive, which is why quantizing outliers coarsely while
//! refining inliers (larger γ) can help.

use super::bar;
use crate::model::{artifacts_dir, TrainedModel};
use anyhow::Result;

pub fn run(_fast: bool) -> Result<()> {
    let m = TrainedModel::load(&artifacts_dir())?;
    // Bucket weights by |w| percentile; report mean sensitivity per bucket
    // over a representative projection.
    for name in ["l1.wq", "l2.w_down"] {
        let (Some(w), Some(s)) = (m.get(name), m.sensitivity_of(name)) else {
            continue;
        };
        let mut pairs: Vec<(f32, f32)> = w
            .data
            .iter()
            .zip(&s.data)
            .map(|(&w, &s)| (w.abs(), s))
            .collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let n = pairs.len();
        println!("\n[{}] mean Fisher sensitivity by |w| percentile:", name);
        let n_buckets = 10;
        let mut means = Vec::new();
        for b in 0..n_buckets {
            let lo = b * n / n_buckets;
            let hi = (b + 1) * n / n_buckets;
            let mean =
                pairs[lo..hi].iter().map(|p| p.1 as f64).sum::<f64>() / (hi - lo) as f64;
            means.push(mean);
        }
        let max = means.iter().cloned().fold(f64::MIN, f64::max);
        for (b, mean) in means.iter().enumerate() {
            let label = if b == n_buckets - 1 { " ← outlier decile" } else { "" };
            println!(
                "p{:>2}-{:<3} {:.3e} {}{}",
                b * 10,
                (b + 1) * 10,
                mean,
                bar(mean / max, 36),
                label
            );
        }
        let center = means[..8].iter().sum::<f64>() / 8.0;
        let tail = means[9];
        println!("center/tail sensitivity ratio: {:.2}", center / tail);
    }
    println!("\npaper Fig 9: distribution tails have markedly lower sensitivity");
    Ok(())
}

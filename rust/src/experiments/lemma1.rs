//! Lemma 1 numeric verification: measured E(B) ≤ bound across a grid of
//! (γ, b, d), with tightness at the operating point.

use super::print_row;
use crate::icq::{lemma1_bound, simulate_overhead};
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let trials = if fast { 100 } else { 500 };
    let widths = [8usize, 4, 7, 11, 11, 9];
    print_row(
        &["γ".into(), "b".into(), "d".into(), "bound".into(), "measured".into(), "tight".into()],
        &widths,
    );
    let mut worst_violation = 0.0f64;
    for &gamma in &[0.02, 0.05, 0.0825, 0.10] {
        for &b in &[4u32, 6, 8] {
            for &d in &[1024usize, 4096] {
                if gamma * (d as f64) < 1.0 {
                    continue;
                }
                let bound = lemma1_bound(gamma, b);
                let measured = simulate_overhead(d, gamma, b, trials, 0xB0);
                let tightness = measured / bound;
                worst_violation = worst_violation.max(tightness);
                print_row(
                    &[
                        format!("{:.2}%", gamma * 100.0),
                        b.to_string(),
                        d.to_string(),
                        format!("{:.4}", bound),
                        format!("{:.4}", measured),
                        format!("{:.3}", tightness),
                    ],
                    &widths,
                );
            }
        }
    }
    println!(
        "\nmax measured/bound = {:.3} (≤ 1 up to MC noise ⇒ Lemma 1 holds; \
         values near 1 ⇒ tight)",
        worst_violation
    );
    Ok(())
}

//! Fig 1: (a) normalized range taken by the top-k% outliers, per layer
//! type, averaged over the model; (b) histogram of one row of weights.

use super::{bar, print_row};
use crate::stats;
use crate::synthzoo::{family, LayerType};
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let f = family("llama2-7b").unwrap();
    let fracs = [0.01, 0.02, 0.03, 0.05, 0.08, 0.10];
    let blocks = if fast { 2 } else { 4 };

    println!("[llama2-7b-sim] Fig 1(a): range share of top-k% outliers");
    let widths = [10usize, 8, 8, 8, 8, 8, 8];
    let mut header = vec!["layer".to_string()];
    header.extend(fracs.iter().map(|f| format!("{:.0}%", f * 100.0)));
    print_row(&header, &widths);

    for lt in LayerType::ALL {
        let mut cells = vec![lt.name().to_string()];
        for &frac in &fracs {
            let mut acc = 0.0;
            for b in 0..blocks {
                let w = f.gen_stat_layer(lt, b);
                acc += stats::avg_range_taken(&w, frac);
            }
            cells.push(format!("{:.3}", acc / blocks as f64));
        }
        print_row(&cells, &widths);
    }
    println!("\npaper: top-5% take ≈0.5 of the range across layer types");

    // (b) histogram of one row.
    println!("\nFig 1(b): histogram of one q_proj row (64 bins)");
    let w = f.gen_stat_layer(LayerType::QProj, 2);
    let row = w.row(7);
    let (edges, counts) = stats::histogram(row, 64);
    let max = *counts.iter().max().unwrap() as f64;
    let k = (row.len() as f64 * 0.05) as usize;
    let outliers = crate::quant::mixed_precision::top_k_by_magnitude(row, k);
    let thresh = outliers.iter().map(|&c| row[c].abs()).fold(f32::INFINITY, f32::min);
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let mid = 0.5 * (edges[i] + edges[i + 1]);
        let marker = if (mid.abs() as f32) >= thresh { " ← outlier region" } else { "" };
        println!("{:>9.4}  {}{}", mid, bar(c as f64 / max, 40), marker);
    }
    println!("\n(5% outlier threshold |w| ≥ {:.4})", thresh);
    Ok(())
}

//! Fig 5: (a) perplexity vs average bits/weight for the outlier
//! suppression techniques on 3-bit RTN; (b) per-block quantization MSE at
//! matched ≈3.3-bit storage.

use super::methods::Method;
use super::{print_row, EvalCtx};
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let mut ctx = EvalCtx::load(fast)?;
    let fp = ctx.ppl_fp()?;
    println!("FP32 baseline ppl: {:.3}\n", fp);

    // (a) ppl vs bits: sweep each technique's knob around 3-bit RTN.
    println!("Fig 5(a): test ppl vs avg bits/weight (3-bit RTN base)");
    let sweeps: Vec<(&str, Vec<Method>)> = vec![
        ("vanilla", vec![Method::Rtn { bits: 3 }, Method::Rtn { bits: 4 }]),
        (
            "grouping",
            vec![
                Method::RtnGroup { bits: 3, group: 128 },
                Method::RtnGroup { bits: 3, group: 64 },
                Method::RtnGroup { bits: 3, group: 32 },
            ],
        ),
        (
            "mixed-precision",
            vec![
                Method::SqueezeLite { bits: 3, ratio: 0.005 },
                Method::SqueezeLite { bits: 3, ratio: 0.01 },
                Method::SqueezeLite { bits: 3, ratio: 0.02 },
            ],
        ),
        (
            "ICQuant^RTN",
            vec![
                Method::IcqRtn { bits: 3, ratio: 0.02 },
                Method::IcqRtn { bits: 3, ratio: 0.05 },
                Method::IcqRtn { bits: 3, ratio: 0.08 },
            ],
        ),
    ];
    let widths = [16usize, 26, 9, 9];
    print_row(
        &["technique".into(), "config".into(), "bits/w".into(), "ppl".into()],
        &widths,
    );
    for (tech, methods) in sweeps {
        for m in methods {
            let (rep, bits) = m.quantize_model(&ctx.model);
            let ppl = ctx.ppl_with(&rep)?;
            print_row(
                &[
                    tech.to_string(),
                    m.name(),
                    format!("{:.2}", bits),
                    format!("{:.3}", ppl),
                ],
                &widths,
            );
        }
    }
    println!("\npaper: ICQuant^RTN has the best ppl-per-bit trade-off; it");
    println!("surpasses 4-bit RTN below 3.2 bits/weight");

    // (b) per-block MSE at ≈3.3 bits for the matched-overhead methods.
    println!("\nFig 5(b): per-block quantization MSE at ≈3.3 bits/weight");
    let methods = [
        Method::Rtn { bits: 3 },
        Method::RtnGroup { bits: 3, group: 64 },
        Method::SqueezeLite { bits: 3, ratio: 0.01 },
        Method::QuipLite { bits: 3 },
        Method::IcqRtn { bits: 3, ratio: 0.05 },
    ];
    let n_layers = ctx.model.config.n_layers;
    let mut header = vec!["method".to_string()];
    header.extend((0..n_layers).map(|i| format!("block{}", i)));
    let w2 = vec![26usize, 10, 10, 10, 10, 10, 10, 10, 10][..1 + n_layers].to_vec();
    print_row(&header, &w2);
    for m in methods {
        let mut cells = vec![m.name()];
        for block in 0..n_layers {
            let mut mse_sum = 0.0;
            let mut n = 0usize;
            for t in ctx.model.projections() {
                if !t.name.starts_with(&format!("l{}.", block)) {
                    continue;
                }
                let w = t.as_matrix();
                let sens = ctx.model.sensitivity_of(&t.name).map(|s| s.as_matrix());
                let (rec, _) = m.quantize_matrix(&w, sens.as_ref(), 7);
                mse_sum += w.sq_err(&rec);
                n += t.numel();
            }
            cells.push(format!("{:.3e}", mse_sum / n as f64));
        }
        print_row(&cells, &w2);
    }
    println!("\npaper: ICQuant^RTN lowest across all blocks (≈1/4 of vanilla);");
    println!("incoherence helps mainly in the first block");
    Ok(())
}

//! Fig 3: (a) vanilla-RTN vs ICQuant layout; (b) the gap-coding example;
//! (c) 2-bit ICQuant matching 3-bit vanilla RTN on a real trained row.

use crate::icq::encode_gaps;
use crate::icquant::{IcqConfig, IcqMatrix};
use crate::model::{artifacts_dir, TrainedModel};
use crate::quant::{self, QuantizerKind};
use crate::util::tensor::Matrix;
use anyhow::Result;

pub fn run(_fast: bool) -> Result<()> {
    // (b) the paper's coding example: positions + b=3 gap symbols.
    println!("Fig 3(b): index coding example (b=3, flag value = 7)");
    let positions = [4usize, 6, 20];
    let symbols = encode_gaps(&positions, 3);
    println!("  outlier positions: {:?}", positions);
    println!("  gaps:              [5, 2, 14]");
    println!("  3-bit symbols:     {:?}  (7 = empty-interval flag)", symbols);

    // (c) 2-bit ICQuant vs 3-bit vanilla on a trained row (fallback to a
    // synthetic row when artifacts are absent).
    let w: Matrix = match TrainedModel::load(&artifacts_dir()) {
        Ok(m) => m.get("l2.w_up").unwrap().as_matrix(),
        Err(_) => crate::synthzoo::demo_matrix(64, 512, 3),
    };

    println!("\nFig 3(a,c): resolution comparison on {}x{} weights", w.rows, w.cols);
    let rtn2 = quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 2).dequantize();
    let rtn3 = quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 3).dequantize();
    let rtn4 = quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 4).dequantize();
    let icq2 = IcqMatrix::quantize(
        &w,
        None,
        &IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 6, quantizer: QuantizerKind::Rtn },
    )?;
    let icq2_d = icq2.dequantize();

    println!("  {:<28} {:>10} {:>12}", "method", "bits/w", "MSE");
    println!("  {:<28} {:>10.2} {:>12.3e}", "vanilla RTN 2-bit", 2.0, w.mse(&rtn2));
    println!(
        "  {:<28} {:>10.2} {:>12.3e}",
        "ICQuant^RTN 2-bit (5%)",
        icq2.avg_bits_per_weight(),
        w.mse(&icq2_d)
    );
    println!("  {:<28} {:>10.2} {:>12.3e}", "vanilla RTN 3-bit", 3.0, w.mse(&rtn3));
    println!("  {:<28} {:>10.2} {:>12.3e}", "vanilla RTN 4-bit", 4.0, w.mse(&rtn4));

    let ratio = w.mse(&icq2_d) / w.mse(&rtn3);
    println!(
        "\n  2.31-bit ICQuant / 3-bit RTN MSE ratio: {:.2} (paper: comparable resolution)",
        ratio
    );
    println!(
        "  2-bit vanilla / 2.31-bit ICQuant:       {:.1}x error reduction",
        w.mse(&rtn2) / w.mse(&icq2_d)
    );
    Ok(())
}

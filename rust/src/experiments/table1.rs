//! Table 1 + Table 5: chi-square rejection rates (α=0.05, groups of 256,
//! γ=6.25 %) across layer types and model families.

use super::print_row;
use crate::stats::rejection_rate;
use crate::synthzoo::{model_families, LayerType};
use anyhow::Result;

pub fn run(fast: bool) -> Result<()> {
    let families = model_families();
    let blocks = if fast { 1 } else { 2 };
    let widths = [12usize, 8, 8, 8, 8, 8, 9, 9];
    let mut header = vec!["model".to_string()];
    header.extend(LayerType::ALL.iter().map(|lt| lt.name().to_string()));
    print_row(&header, &widths);

    let selected: Vec<_> = if fast {
        families
            .into_iter()
            .filter(|f| matches!(f.name, "llama2-7b" | "llama3-8b"))
            .collect()
    } else {
        families
    };

    for f in &selected {
        let mut cells = vec![f.name.to_string()];
        for lt in LayerType::ALL {
            let mut acc = 0.0;
            for b in 0..blocks {
                let w = f.gen_stat_layer(lt, b * 2);
                acc += rejection_rate(&w, 0.0625, 256, 0.05);
            }
            cells.push(format!("{:.2}%", acc / blocks as f64 * 100.0));
        }
        print_row(&cells, &widths);
    }
    println!("\npaper Table 1/5: q/k/v/up/gate/down ≈2–4%; o_proj 59–95%");
    Ok(())
}

//! Synthetic zero-shot task suite (ArcC/ArcE/PiQA/WinoGrande stand-ins).
//!
//! Each task is multiple-choice continuation: a corpus context plus four
//! candidate continuations, scored by **length-normalized answer NLL**
//! exactly like LM-Eval-Harness scores real zero-shot tasks. The four
//! suites differ in distractor construction, giving a difficulty ladder:
//!
//! * `arce-sim`  — distractors drawn from distant corpus positions (easy:
//!   topical mismatch).
//! * `piqa-sim`  — distractors are other continuations of *similar*
//!   contexts (medium).
//! * `arcc-sim`  — distractors are the true continuation with word-level
//!   shuffling (hard: locally plausible).
//! * `wino-sim`  — distractors differ from the truth in a few characters
//!   (hardest: near-duplicate discrimination).
//!
//! Accuracy deltas across quantization methods flow through the same
//! scoring machinery as the paper's Table 3/6/7/8.

use crate::runtime::{Engine, HostTensor};
use crate::util::prng::Rng;
use anyhow::{Context, Result};

/// One multiple-choice question over byte tokens.
#[derive(Clone, Debug)]
pub struct Question {
    /// Shared context tokens (length = ctx_len).
    pub context: Vec<i32>,
    /// Four candidate continuations (each choice_len tokens).
    pub choices: [Vec<i32>; 4],
    pub answer: usize,
}

/// A generated task suite.
pub struct Task {
    pub name: &'static str,
    pub questions: Vec<Question>,
    pub ctx_len: usize,
    pub choice_len: usize,
}

fn chunk(tokens: &[i32], start: usize, len: usize) -> Vec<i32> {
    tokens[start..start + len].to_vec()
}

/// Word-shuffle a token chunk (splits on spaces, shuffles word order).
fn word_shuffle(chunk: &[i32], rng: &mut Rng) -> Vec<i32> {
    let bytes: Vec<u8> = chunk.iter().map(|&t| t as u8).collect();
    let mut words: Vec<&[u8]> = bytes.split(|&b| b == b' ').collect();
    if words.len() > 2 {
        rng.shuffle(&mut words);
    }
    let mut out: Vec<i32> = Vec::with_capacity(chunk.len());
    for (i, w) in words.iter().enumerate() {
        if i > 0 {
            out.push(b' ' as i32);
        }
        out.extend(w.iter().map(|&b| b as i32));
    }
    out.resize(chunk.len(), b' ' as i32);
    out
}

/// Flip a few characters (wino-style minimal pairs).
fn char_corrupt(chunk: &[i32], n_flips: usize, rng: &mut Rng) -> Vec<i32> {
    let mut out = chunk.to_vec();
    for _ in 0..n_flips {
        let i = rng.below(out.len() as u64) as usize;
        if out[i] != b' ' as i32 {
            // Swap to a nearby lowercase letter.
            out[i] = b'a' as i32 + rng.below(26) as i64 as i32;
        }
    }
    out
}

/// Generate the four task suites from a corpus split.
pub fn generate_tasks(
    tokens: &[i32],
    n_questions: usize,
    ctx_len: usize,
    choice_len: usize,
    seed: u64,
) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    let span = ctx_len + choice_len;
    let usable = tokens.len() - span - 1;

    let mut mk = |name: &'static str, style: u8| -> Task {
        let mut questions = Vec::with_capacity(n_questions);
        for q in 0..n_questions {
            // Deterministic, spread-out question positions.
            let start = (q * 7919 + 13) % usable;
            let context = chunk(tokens, start, ctx_len);
            let truth = chunk(tokens, start + ctx_len, choice_len);
            let mut choices: [Vec<i32>; 4] = Default::default();
            let answer = rng.below(4) as usize;
            for (c, slot) in choices.iter_mut().enumerate() {
                if c == answer {
                    *slot = truth.clone();
                    continue;
                }
                *slot = match style {
                    // arce: distant text.
                    0 => {
                        let far = (start + usable / 2 + c * 104729) % usable;
                        chunk(tokens, far + ctx_len, choice_len)
                    }
                    // piqa: continuation of a *nearby* context.
                    1 => {
                        let near = (start + 997 * (c + 1)) % usable;
                        chunk(tokens, near + ctx_len, choice_len)
                    }
                    // arcc: shuffled truth.
                    2 => word_shuffle(&truth, &mut rng),
                    // wino: minimal character corruption.
                    _ => char_corrupt(&truth, 3, &mut rng),
                };
            }
            questions.push(Question { context, choices, answer });
        }
        Task { name, questions, ctx_len, choice_len }
    };

    vec![
        mk("arce-sim", 0),
        mk("piqa-sim", 1),
        mk("arcc-sim", 2),
        mk("wino-sim", 3),
    ]
}

/// Score one task with the `token_nll_b4` entry: the 4 choices of each
/// question form one batch; answer = argmin length-normalized NLL over
/// the choice span.
pub fn score_task(
    engine: &mut Engine,
    weights: Vec<xla::Literal>,
    task: &Task,
) -> Result<f64> {
    let bufs = engine.upload_all(weights)?;
    score_task_resident(engine, &bufs, task)
}

/// Score with device-resident weights (shared across tasks/windows).
pub fn score_task_resident(
    engine: &mut Engine,
    weights: &[crate::runtime::ResidentBuffer],
    task: &Task,
) -> Result<f64> {
    let b = engine.manifest().eval_batch;
    anyhow::ensure!(b == 4, "task scoring expects eval batch 4");
    let entry = format!("token_nll_b{}", b);
    let s = engine
        .manifest()
        .entries
        .get(&entry)
        .context("token_nll entry missing")?
        .inputs[0]
        .shape[1];
    anyhow::ensure!(
        task.ctx_len + task.choice_len <= s,
        "question longer than eval sequence"
    );
    engine.prepare(&entry)?; // compile before async data uploads begin

    let mut correct = 0usize;
    for q in &task.questions {
        // Build 4 sequences: context ++ choice, padded to S.
        let mut toks = Vec::with_capacity(4 * s);
        let mut targets = Vec::with_capacity(4 * s);
        for c in 0..4 {
            let mut seq: Vec<i32> = Vec::with_capacity(s + 1);
            seq.extend_from_slice(&q.context);
            seq.extend_from_slice(&q.choices[c]);
            seq.resize(s + 1, b' ' as i32);
            toks.extend_from_slice(&seq[..s]);
            targets.extend_from_slice(&seq[1..s + 1]);
        }
        let data = [
            engine.upload(HostTensor::I32(toks, vec![4, s]).to_literal()?)?,
            engine.upload(HostTensor::I32(targets, vec![4, s]).to_literal()?)?,
        ];
        let args: Vec<&crate::runtime::ResidentBuffer> = data.iter().chain(weights.iter()).collect();
        let out = engine.execute_buffers(&entry, &args)?;
        let nll = Engine::literal_f32(&out[0])?; // [4, S] row-major

        // Length-normalized NLL over the choice span:
        // predictions for positions ctx_len-1 .. ctx_len+choice_len-2.
        let lo = task.ctx_len - 1;
        let hi = lo + task.choice_len;
        let mut best = (f32::INFINITY, 0usize);
        for c in 0..4 {
            let row = &nll[c * s..(c + 1) * s];
            let score: f32 =
                row[lo..hi].iter().sum::<f32>() / task.choice_len as f32;
            if score < best.0 {
                best = (score, c);
            }
        }
        if best.1 == q.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / task.questions.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_tokens(n: usize) -> Vec<i32> {
        // Structured "text": repeating words with variation.
        let words = [&b"alpha "[..], &b"beta "[..], &b"gamma "[..], &b"delta "[..]];
        let mut out = Vec::with_capacity(n + 16);
        let mut i = 0usize;
        while out.len() < n {
            let w = words[(i * i + 3 * i) % 4];
            out.extend(w.iter().map(|&b| b as i32));
            i += 1;
        }
        out.truncate(n);
        out
    }

    #[test]
    fn task_generation_shapes() {
        let toks = fake_tokens(50_000);
        let tasks = generate_tasks(&toks, 20, 96, 32, 7);
        assert_eq!(tasks.len(), 4);
        for t in &tasks {
            assert_eq!(t.questions.len(), 20);
            for q in &t.questions {
                assert_eq!(q.context.len(), 96);
                for c in &q.choices {
                    assert_eq!(c.len(), 32);
                }
                assert!(q.answer < 4);
                // Truth must be present at the answer slot and the
                // distractors must differ from it.
                let truth = &q.choices[q.answer];
                let n_same = q.choices.iter().filter(|c| *c == truth).count();
                assert!(n_same >= 1);
            }
        }
    }

    #[test]
    fn answers_are_balanced() {
        let toks = fake_tokens(80_000);
        let tasks = generate_tasks(&toks, 100, 64, 16, 11);
        for t in &tasks {
            let mut counts = [0usize; 4];
            for q in &t.questions {
                counts[q.answer] += 1;
            }
            for &c in &counts {
                assert!(c > 10, "{}: answer distribution {:?}", t.name, counts);
            }
        }
    }

    #[test]
    fn corruptions_preserve_length() {
        let mut rng = Rng::new(3);
        let chunk: Vec<i32> = b"the quick brown fox jumps".iter().map(|&b| b as i32).collect();
        assert_eq!(word_shuffle(&chunk, &mut rng).len(), chunk.len());
        assert_eq!(char_corrupt(&chunk, 3, &mut rng).len(), chunk.len());
        // char corruption changes at most 3 positions.
        let corrupted = char_corrupt(&chunk, 3, &mut rng);
        let diffs = chunk.iter().zip(&corrupted).filter(|(a, b)| a != b).count();
        assert!(diffs <= 3);
    }

    #[test]
    fn generation_is_deterministic() {
        let toks = fake_tokens(50_000);
        let a = generate_tasks(&toks, 10, 96, 32, 7);
        let b = generate_tasks(&toks, 10, 96, 32, 7);
        for (x, y) in a.iter().zip(&b) {
            for (qx, qy) in x.questions.iter().zip(&y.questions) {
                assert_eq!(qx.answer, qy.answer);
                assert_eq!(qx.context, qy.context);
            }
        }
    }
}

//! Evaluation harnesses: perplexity over the held-out corpus and the
//! synthetic zero-shot task suite — the measurement side of every table
//! in §4.
//!
//! Both run through the PJRT engine on AOT-lowered HLO: the same code
//! path a deployment would use, with weights passed positionally
//! (FP or dequantized-from-ICQuant — the quantization methods only differ
//! in what weight values they produce).

pub mod tasks;

use crate::model::TrainedModel;
use crate::runtime::{Engine, HostTensor};
use anyhow::{Context, Result};
use std::path::Path;

/// Build the positional weight literals for the FP entries once;
/// reusable across every execute call.
pub fn weight_literals(model: &TrainedModel) -> Result<Vec<xla::Literal>> {
    model
        .tensors
        .iter()
        .map(|t| HostTensor::F32(t.data.clone(), t.shape.clone()).to_literal())
        .collect()
}

/// Upload a model's weights to the device once (§Perf: every eval window
/// then borrows the resident buffers instead of re-copying ~4 MiB).
pub fn upload_weights(engine: &Engine, model: &TrainedModel) -> Result<Vec<crate::runtime::ResidentBuffer>> {
    engine.upload_all(weight_literals(model)?)
}

/// Load a corpus split as i32 tokens.
pub fn load_corpus_tokens(dir: &Path, split: &str) -> Result<Vec<i32>> {
    let path = dir.join(format!("corpus_{}.bin", split));
    let bytes = std::fs::read(&path)
        .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
    Ok(bytes.into_iter().map(|b| b as i32).collect())
}

/// Perplexity of a model (given as weight literals) over token windows.
///
/// Uses the `forward_loss_b{B}` entry: `windows` batches of B sequences of
/// length S are drawn at a fixed stride from `tokens` (deterministic —
/// every method sees the same data).
pub fn perplexity(
    engine: &mut Engine,
    weights: Vec<xla::Literal>,
    tokens: &[i32],
    windows: usize,
) -> Result<f64> {
    let bufs = engine.upload_all(weights)?;
    perplexity_resident(engine, &bufs, tokens, windows)
}

/// Perplexity with device-resident weight buffers (see
/// [`upload_weights`]).
pub fn perplexity_resident(
    engine: &mut Engine,
    weights: &[crate::runtime::ResidentBuffer],
    tokens: &[i32],
    windows: usize,
) -> Result<f64> {
    let b = engine.manifest().eval_batch;
    let s = engine
        .manifest()
        .entries
        .get(&format!("forward_loss_b{}", b))
        .context("forward_loss entry missing")?
        .inputs[0]
        .shape[1];
    let entry = format!("forward_loss_b{}", b);
    engine.prepare(&entry)?; // compile before async data uploads begin

    let needed = b * (s + 1);
    let max_start = tokens.len().saturating_sub(needed + 1);
    anyhow::ensure!(max_start > 0, "eval corpus too small");
    let stride = (max_start / windows.max(1)).max(1);

    let mut total_nll = 0.0f64;
    for w in 0..windows {
        let base = w * stride;
        let mut toks = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for seq in 0..b {
            let start = base + seq * (s + 1);
            toks.extend_from_slice(&tokens[start..start + s]);
            targets.extend_from_slice(&tokens[start + 1..start + s + 1]);
        }
        let data = [
            engine.upload(HostTensor::I32(toks, vec![b, s]).to_literal()?)?,
            engine.upload(HostTensor::I32(targets, vec![b, s]).to_literal()?)?,
        ];
        let args: Vec<&crate::runtime::ResidentBuffer> = data.iter().chain(weights.iter()).collect();
        let out = engine.execute_buffers(&entry, &args)?;
        total_nll += Engine::scalar_f32(&out[0])? as f64;
    }
    Ok((total_nll / windows as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_loader() {
        let dir = std::env::temp_dir().join("icq_eval_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("corpus_test.bin"), [65u8, 66, 255]).unwrap();
        let toks = load_corpus_tokens(&dir, "test").unwrap();
        assert_eq!(toks, vec![65, 66, 255]);
        assert!(load_corpus_tokens(&dir, "absent").is_err());
    }
}

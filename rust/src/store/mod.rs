//! ICQZ model store: the quantized-checkpoint lifecycle, end to end.
//!
//! The paper's deliverable is the deployed artifact — its on-disk size
//! *is* the ≈(n+0.3)-bit/weight claim — so this subsystem owns everything
//! between "quantized matrices in memory" and "weights resident in the
//! serving backend":
//!
//! * [`container`] — the `ICQZ` v1 **single-file container**: every
//!   layer's [`IcqMatrix`] (embedded `ICQM` payloads) plus the f32 side
//!   tensors (norms, embeddings) and the [`ModelConfig`], behind a JSON
//!   table-of-contents with 64-byte-aligned sections (mmap-ready),
//!   per-section CRC32 checksums, and exact bits/weight accounting in
//!   the header.
//! * [`registry`] — an on-disk **artifact registry**: content-hash-named
//!   container files plus a manifest JSON, so the coordinator and eval
//!   harnesses resolve models by `name@hash` instead of ad-hoc paths
//!   (`put` / `get` / `list` / `verify` / `gc`).
//! * [`cache`] — a byte-budget **LRU decode cache** holding fused
//!   *runtime planes* (the [`crate::icquant::runtime`] decode:
//!   bit-packed (n+1)-bit codes + flat codebooks, ≈(n+1)/32 of f32) so
//!   repeated prefill/decode batches never re-decode the same layer and
//!   the byte budget stretches ≈10× further at 2-bit than caching
//!   dequantized f32 would (DESIGN.md §6).
//!
//! [`StoredModel`] ties the three together for the serving stack: open a
//! container (usually resolved through the registry), keep the quantized
//! form resident, and hand out runtime planes through the shared cache —
//! the native kernels ([`crate::kernels`]) consume them directly; the
//! PJRT weight-upload path dequantizes transiently.
//!
//! ```text
//! quantize ─► IcqzModel ─► container::save ─► registry::put ─┐
//!                                                            ▼
//!       native kernels ◄─ RuntimePlane ◄─ DecodeCache ◄─ StoredModel::open
//!   PJRT ◄─ TrainedModel ◄─ (transient dequantize) ◄┘
//! ```

pub mod cache;
pub mod container;
pub mod registry;

pub use cache::{CacheStats, DecodeCache};
pub use container::{IcqzModel, TensorPayload};
pub use registry::Registry;

use crate::icquant::runtime::RuntimePlane;
use crate::icquant::{IcqConfig, IcqMatrix};
use crate::model::{ModelConfig, NamedTensor, TrainedModel};
use crate::synthzoo::{FamilySpec, LayerType};
use crate::util::prng::Rng;
use crate::util::tensor::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

enum StoredPayload {
    Quantized(Arc<IcqMatrix>),
    Dense { shape: Vec<usize>, data: Vec<f32> },
}

/// A container opened for serving: quantized layers stay packed in
/// memory; dense planes are materialized on demand through a shared
/// [`DecodeCache`], so every consumer (coordinator backends, eval
/// harnesses, benches) of the same artifact shares one decode.
pub struct StoredModel {
    pub config: Option<ModelConfig>,
    pub val_loss: f64,
    entries: Vec<(String, StoredPayload)>,
    cache: Arc<DecodeCache>,
    key_prefix: String,
}

impl StoredModel {
    /// Open an `ICQZ` container file with the given decode cache.
    pub fn open(path: &Path, cache: Arc<DecodeCache>) -> Result<StoredModel> {
        let model = container::load(path)?;
        Ok(Self::from_model(model, cache, &path.display().to_string()))
    }

    /// Wrap an in-memory [`IcqzModel`]; `key_prefix` namespaces this
    /// artifact's layers in the shared cache (use the container path or
    /// the registry hash).
    pub fn from_model(
        model: IcqzModel,
        cache: Arc<DecodeCache>,
        key_prefix: &str,
    ) -> StoredModel {
        let entries = model
            .entries
            .into_iter()
            .map(|(name, payload)| {
                let stored = match payload {
                    TensorPayload::Quantized(m) => StoredPayload::Quantized(Arc::new(m)),
                    TensorPayload::Dense { shape, data } => {
                        StoredPayload::Dense { shape, data }
                    }
                };
                (name, stored)
            })
            .collect();
        StoredModel {
            config: model.config,
            val_loss: model.val_loss,
            entries,
            cache,
            key_prefix: key_prefix.to_string(),
        }
    }

    pub fn cache(&self) -> &Arc<DecodeCache> {
        &self.cache
    }

    /// Names of the quantized (projection) layers, in container order.
    pub fn quantized_names(&self) -> Vec<&str> {
        self.entries
            .iter()
            .filter(|(_, p)| matches!(p, StoredPayload::Quantized(_)))
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// Fused runtime plane for a quantized layer, through the LRU cache:
    /// a hit is a map lookup; a miss runs the fused runtime decode
    /// ([`IcqMatrix::to_runtime`]) exactly once. This is what the native
    /// serving kernels ([`crate::kernels`]) consume.
    pub fn runtime_plane(&self, name: &str) -> Result<Arc<RuntimePlane>> {
        let (_, payload) = self
            .entries
            .iter()
            .find(|(n, _)| n == name)
            .with_context(|| format!("no tensor '{}' in container", name))?;
        match payload {
            StoredPayload::Quantized(m) => {
                let key = format!("{}/{}", self.key_prefix, name);
                Ok(self.cache.get_or_decode(&key, m))
            }
            StoredPayload::Dense { .. } => {
                bail!("tensor '{}' is a dense side tensor, not quantized", name)
            }
        }
    }

    /// Dense f32 plane for a quantized layer: the cached runtime plane
    /// dequantized **transiently** — the f32 copy belongs to the caller
    /// and is never held (or byte-charged) by the cache.
    pub fn decode(&self, name: &str) -> Result<Matrix> {
        Ok(self.runtime_plane(name)?.dequantize())
    }

    /// Shape + data of a dense (non-quantized) side tensor.
    pub fn dense(&self, name: &str) -> Result<(&[usize], &[f32])> {
        let (_, payload) = self
            .entries
            .iter()
            .find(|(n, _)| n == name)
            .with_context(|| format!("no tensor '{}' in container", name))?;
        match payload {
            StoredPayload::Dense { shape, data } => Ok((shape.as_slice(), data.as_slice())),
            StoredPayload::Quantized(_) => {
                bail!("tensor '{}' is quantized; use runtime_plane/decode", name)
            }
        }
    }

    /// Materialize the full f32 model for a backend that consumes
    /// [`TrainedModel`] (the PJRT weight-upload path). Quantized layers
    /// go through the runtime-plane cache and are dequantized
    /// transiently into the returned model (the cache keeps only the
    /// quantized form); container order is preserved — it is the
    /// positional ABI the AOT-compiled HLO entries expect.
    pub fn to_trained_model(&self) -> Result<TrainedModel> {
        let config = self
            .config
            .clone()
            .context("container carries no model config; cannot build a servable model")?;
        let mut tensors = Vec::with_capacity(self.entries.len());
        for (name, payload) in &self.entries {
            let t = match payload {
                StoredPayload::Dense { shape, data } => NamedTensor {
                    name: name.clone(),
                    shape: shape.clone(),
                    data: data.clone(),
                },
                StoredPayload::Quantized(m) => {
                    let key = format!("{}/{}", self.key_prefix, name);
                    let plane = self.cache.get_or_decode(&key, m);
                    NamedTensor {
                        name: name.clone(),
                        shape: vec![m.rows, m.cols],
                        data: plane.dequantize().data,
                    }
                }
            };
            tensors.push(t);
        }
        Ok(TrainedModel::from_parts(config, tensors, Vec::new(), self.val_loss))
    }
}

/// Quantize every projection of a trained model into an [`IcqzModel`]
/// (side tensors ride along dense), preserving tensor order.
pub fn quantize_trained(model: &TrainedModel, cfg: &IcqConfig) -> Result<IcqzModel> {
    let mut entries = Vec::with_capacity(model.tensors.len());
    for t in &model.tensors {
        let payload = if t.is_projection() {
            let sens = model.sensitivity_of(&t.name).map(|s| s.as_matrix());
            let q = IcqMatrix::quantize(&t.as_matrix(), sens.as_ref(), cfg)
                .with_context(|| format!("quantize {}", t.name))?;
            TensorPayload::Quantized(q)
        } else {
            TensorPayload::Dense { shape: t.shape.clone(), data: t.data.clone() }
        };
        entries.push((t.name.clone(), payload));
    }
    Ok(IcqzModel {
        config: Some(model.config.clone()),
        val_loss: model.val_loss,
        entries,
    })
}

/// Build and quantize a synthetic checkpoint from a SynthZoo family —
/// the `icquant pack` path on a box that holds no real checkpoints.
/// Layout follows the python `param_spec` ABI exactly
/// (`tok_emb`, per-block norms + 7 projections, `final_norm`, `lm_head`),
/// so [`TrainedModel::validate`] passes on the reconstruction.
pub fn synth_model(
    family: &FamilySpec,
    cfg: &IcqConfig,
    max_blocks: Option<usize>,
) -> Result<IcqzModel> {
    let n_layers = match max_blocks {
        Some(b) => {
            ensure!(b >= 1, "need at least one block");
            b.min(family.n_blocks)
        }
        None => family.n_blocks,
    };
    let vocab = 256usize;
    let config = ModelConfig {
        vocab,
        d_model: family.d_model,
        n_layers,
        n_heads: 4,
        d_ff: family.d_ff,
        max_seq: 256,
    };
    let mut rng = Rng::new(family.seed ^ 0x1C02_5EED);
    let mut entries = Vec::new();
    let dense_mat = |m: Matrix| TensorPayload::Dense {
        shape: vec![m.rows, m.cols],
        data: m.data,
    };
    let norm = |rng: &mut Rng, n: usize| TensorPayload::Dense {
        shape: vec![n],
        data: (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.02).collect(),
    };
    let quantize = |w: &Matrix, name: &str| -> Result<TensorPayload> {
        let q = IcqMatrix::quantize(w, None, cfg).with_context(|| format!("quantize {}", name))?;
        Ok(TensorPayload::Quantized(q))
    };

    entries.push((
        "tok_emb".to_string(),
        dense_mat(crate::synthzoo::demo_matrix(vocab, family.d_model, family.seed ^ 0xE0B)),
    ));
    const PROJS: [(LayerType, &str); 7] = [
        (LayerType::QProj, "wq"),
        (LayerType::KProj, "wk"),
        (LayerType::VProj, "wv"),
        (LayerType::OProj, "wo"),
        (LayerType::GateProj, "w_gate"),
        (LayerType::UpProj, "w_up"),
        (LayerType::DownProj, "w_down"),
    ];
    for block in 0..n_layers {
        entries.push((format!("l{}.attn_norm", block), norm(&mut rng, family.d_model)));
        for (lt, suffix) in PROJS {
            if suffix == "w_gate" {
                entries.push((format!("l{}.mlp_norm", block), norm(&mut rng, family.d_model)));
            }
            let name = format!("l{}.{}", block, suffix);
            let w = family.gen_layer(lt, block);
            entries.push((name.clone(), quantize(&w, &name)?));
        }
    }
    entries.push(("final_norm".to_string(), norm(&mut rng, family.d_model)));
    entries.push((
        "lm_head".to_string(),
        dense_mat(crate::synthzoo::demo_matrix(vocab, family.d_model, family.seed ^ 0x1EAD)),
    ));

    Ok(IcqzModel { config: Some(config), val_loss: f64::NAN, entries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizerKind;
    use crate::synthzoo;

    fn tiny_cfg() -> IcqConfig {
        IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        }
    }

    #[test]
    fn synth_model_matches_param_spec_abi() {
        let f = synthzoo::family("llama3.2-1b").unwrap();
        let model = synth_model(&f, &tiny_cfg(), Some(2)).unwrap();
        // 1 + 9·layers + 2 tensors, in ABI order.
        assert_eq!(model.entries.len(), 1 + 9 * 2 + 2);
        assert_eq!(model.entries[0].0, "tok_emb");
        assert_eq!(model.entries[1].0, "l0.attn_norm");
        assert_eq!(model.entries[6].0, "l0.mlp_norm");
        assert_eq!(model.entries.last().unwrap().0, "lm_head");
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache, "test");
        let tm = stored.to_trained_model().unwrap();
        tm.validate().unwrap();
        assert_eq!(stored.quantized_names().len(), 7 * 2);
    }

    #[test]
    fn decode_goes_through_cache() {
        let f = synthzoo::family("llama3.2-1b").unwrap();
        let model = synth_model(&f, &tiny_cfg(), Some(1)).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache.clone(), "t");
        let a = stored.runtime_plane("l0.wq").unwrap();
        let b = stored.runtime_plane("l0.wq").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        // The cache is charged the packed runtime-plane size — smaller
        // than one byte per code, let alone f32.
        assert_eq!(cache.bytes_used(), a.memory_bytes());
        assert!(cache.bytes_used() < a.rows * a.cols);
        // decode() dequantizes transiently off the same cached plane.
        let d1 = stored.decode("l0.wq").unwrap();
        assert_eq!(d1.data, a.dequantize().data);
        assert_eq!(cache.stats().misses, 1, "decode must reuse the plane");
        // Dense tensors are not cacheable decodes (but readable raw).
        assert!(stored.decode("tok_emb").is_err());
        assert!(stored.runtime_plane("tok_emb").is_err());
        assert!(stored.dense("tok_emb").is_ok());
        assert!(stored.dense("l0.wq").is_err());
        assert!(stored.decode("nope").is_err());
    }

    #[test]
    fn quantize_trained_round_trips_through_stored_model() {
        // Build a trained-model stand-in from the synth builder itself.
        let f = synthzoo::family("llama3.2-1b").unwrap();
        let m = synth_model(&f, &tiny_cfg(), Some(1)).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let tm = StoredModel::from_model(m, cache.clone(), "a").to_trained_model().unwrap();
        let re = quantize_trained(&tm, &tiny_cfg()).unwrap();
        assert_eq!(re.entries.len(), tm.tensors.len());
        let tm2 = StoredModel::from_model(re, cache, "b").to_trained_model().unwrap();
        tm2.validate().unwrap();
        assert_eq!(tm2.tensors[0].data, tm.tensors[0].data); // dense untouched
    }
}

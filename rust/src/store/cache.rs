//! Byte-budget LRU cache for dequantized weight planes.
//!
//! The serving hot loop wants dense f32 planes; the store keeps layers
//! in their ≈2.3-bit packed form. [`DecodeCache`] sits between them:
//! `get_or_decode` runs the fused runtime decode
//! ([`IcqMatrix::to_runtime`] → dequantize) at most once per key while
//! the entry is resident, so repeated prefill/decode batches — and
//! multiple consumers of the same artifact — share one decode.
//!
//! Eviction is least-recently-used over a *byte* budget (weight planes
//! vary by orders of magnitude across layers, so an entry-count bound
//! would be meaningless). Victim selection scans the table; the table
//! holds one entry per model layer (dozens), so the scan is noise next
//! to a single plane decode. Entries are handed out as `Arc<Matrix>` —
//! eviction never invalidates a plane a consumer still holds.

use crate::icquant::IcqMatrix;
use crate::util::tensor::Matrix;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Counters since construction (monotonic; read via [`DecodeCache::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Total bytes produced by decodes (including later-evicted planes).
    pub decoded_bytes: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    plane: Arc<Matrix>,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    bytes: usize,
    tick: u64,
    stats: CacheStats,
}

/// Thread-safe byte-budget LRU decode cache (shared via `Arc`).
pub struct DecodeCache {
    inner: Mutex<Inner>,
    budget_bytes: usize,
}

impl DecodeCache {
    pub fn new(budget_bytes: usize) -> DecodeCache {
        DecodeCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                bytes: 0,
                tick: 0,
                stats: CacheStats::default(),
            }),
            budget_bytes,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// The dense plane for `key`, decoding `m` on a miss.
    pub fn get_or_decode(&self, key: &str, m: &IcqMatrix) -> Arc<Matrix> {
        self.get_or_insert_with(key, || m.to_runtime().dequantize())
    }

    /// General form: `decode` runs only on a miss. It executes under the
    /// cache lock (decodes are CPU-bound and the lock is per-cache, not
    /// per-request); `decode` must not touch this cache.
    pub fn get_or_insert_with<F>(&self, key: &str, decode: F) -> Arc<Matrix>
    where
        F: FnOnce() -> Matrix,
    {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        let now = inner.tick;
        if let Some(e) = inner.map.get_mut(key) {
            e.last_used = now;
            inner.stats.hits += 1;
            return e.plane.clone();
        }
        let plane = Arc::new(decode());
        let bytes = plane.numel() * 4;
        inner.stats.misses += 1;
        inner.stats.decoded_bytes += bytes as u64;
        inner.bytes += bytes;
        inner
            .map
            .insert(key.to_string(), Entry { plane: plane.clone(), bytes, last_used: now });
        // Evict LRU entries (never the one just inserted) until within
        // budget. A single over-budget plane stays resident — the cache
        // must still serve it.
        while inner.bytes > self.budget_bytes && inner.map.len() > 1 {
            let victim = inner
                .map
                .iter()
                .filter(|(k, _)| k.as_str() != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = inner.map.remove(&k).expect("victim vanished");
                    inner.bytes -= e.bytes;
                    inner.stats.evictions += 1;
                }
                None => break,
            }
        }
        plane
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes (≤ budget except for a single oversized plane).
    pub fn bytes_used(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Drop every resident plane (stats are preserved).
    pub fn clear(&self) {
        let mut guard = self.inner.lock().unwrap();
        guard.map.clear();
        guard.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::synthzoo;

    fn plane(seed: u64) -> Matrix {
        synthzoo::demo_matrix(8, 32, seed) // 1 KiB each
    }

    #[test]
    fn hit_returns_same_arc_and_counts() {
        let c = DecodeCache::new(1 << 20);
        let a = c.get_or_insert_with("x", || plane(1));
        let b = c.get_or_insert_with("x", || panic!("decode ran on a hit"));
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_used(), 8 * 32 * 4);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // Budget fits exactly two 1 KiB planes.
        let c = DecodeCache::new(2 * 1024);
        c.get_or_insert_with("a", || plane(1));
        c.get_or_insert_with("b", || plane(2));
        // Touch "a" so "b" is the LRU victim.
        c.get_or_insert_with("a", || panic!("hit expected"));
        c.get_or_insert_with("c", || plane(3));
        assert_eq!(c.len(), 2);
        assert!(c.bytes_used() <= 2 * 1024);
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        // "a" survived (and is refreshed again by this touch).
        c.get_or_insert_with("a", || panic!("'a' should still be resident"));
        // "b" was evicted; re-fetching decodes again (evicting "c",
        // which is now the least recently used).
        let before = c.stats().misses;
        c.get_or_insert_with("b", || plane(2));
        assert_eq!(c.stats().misses, before + 1);
    }

    #[test]
    fn oversized_single_entry_stays_resident() {
        let c = DecodeCache::new(16); // absurdly small budget
        let a = c.get_or_insert_with("big", || plane(7));
        assert_eq!(c.len(), 1);
        let b = c.get_or_insert_with("big", || panic!("must hit"));
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn decode_cache_decodes_icq_matrices_once() {
        let w = synthzoo::demo_matrix(16, 256, 9);
        let q = crate::icquant::IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let c = DecodeCache::new(1 << 20);
        let d1 = c.get_or_decode("m", &q);
        let d2 = c.get_or_decode("m", &q);
        assert!(Arc::ptr_eq(&d1, &d2));
        assert_eq!(d1.data, q.to_runtime().dequantize().data);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn clear_preserves_stats() {
        let c = DecodeCache::new(1 << 20);
        c.get_or_insert_with("a", || plane(1));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn shared_across_threads() {
        let c = Arc::new(DecodeCache::new(1 << 20));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..8 {
                    let _ = c.get_or_insert_with(&format!("k{}", i), || plane(i as u64));
                }
                t
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 32);
        assert_eq!(c.len(), 8);
        assert_eq!(s.misses, 8);
    }
}

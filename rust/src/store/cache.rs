//! Byte-budget LRU cache for fused runtime weight planes.
//!
//! The serving hot loop consumes quantized layers through the fused
//! kernels ([`crate::kernels`]), so what is worth caching is the
//! **runtime plane** — bit-packed (n+1)-bit codes plus the flat fused
//! codebook buffer ([`IcqMatrix::to_runtime`]), ≈(n+1)/32 the bytes of a
//! dequantized f32 plane (~3 bits/weight at n=2). [`DecodeCache`] sits
//! between the ≈2.3-bit storage form and the kernels: `get_or_decode`
//! runs the storage→runtime decode at most once per key while the entry
//! is resident, so repeated prefill/decode batches — and multiple
//! consumers of the same artifact — share one decode. Holding packed
//! planes instead of f32 stretches the same byte budget ≈10× at 2-bit
//! LLM widths — and ≈2.6× further than the byte-aligned v1 plane did,
//! so a budget that used to hold a model's worth of byte planes now
//! holds ~8/(n+1)× more layers (DESIGN.md §6). Consumers that do need
//! f32 (the PJRT weight-upload path) dequantize transiently from the
//! cached plane and drop the f32 copy after use.
//!
//! Each entry is charged its **true** resident size,
//! [`RuntimePlane::memory_bytes`] (packed code bytes incl. row padding +
//! codebook bytes) — not the f32 plane size, not a byte-per-code size,
//! and not the storage size.
//!
//! Eviction is least-recently-used over a *byte* budget (weight planes
//! vary by orders of magnitude across layers, so an entry-count bound
//! would be meaningless). Victim selection scans the table; the table
//! holds one entry per model layer (dozens), so the scan is noise next
//! to a single plane decode. Entries are handed out as
//! `Arc<RuntimePlane>` — eviction never invalidates a plane a consumer
//! still holds.

use crate::icquant::runtime::RuntimePlane;
use crate::icquant::IcqMatrix;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Counters since construction (monotonic; read via [`DecodeCache::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Total runtime-plane bytes produced by decodes (including
    /// later-evicted planes).
    pub decoded_bytes: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    plane: Arc<RuntimePlane>,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    bytes: usize,
    tick: u64,
    stats: CacheStats,
}

/// Thread-safe byte-budget LRU runtime-plane cache (shared via `Arc`).
pub struct DecodeCache {
    inner: Mutex<Inner>,
    budget_bytes: usize,
}

impl DecodeCache {
    pub fn new(budget_bytes: usize) -> DecodeCache {
        DecodeCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                bytes: 0,
                tick: 0,
                stats: CacheStats::default(),
            }),
            budget_bytes,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// The runtime plane for `key`, decoding `m` on a miss.
    pub fn get_or_decode(&self, key: &str, m: &IcqMatrix) -> Arc<RuntimePlane> {
        self.get_or_insert_with(key, || m.to_runtime())
    }

    /// General form: `decode` runs only on a miss. It executes under the
    /// cache lock (decodes are CPU-bound and the lock is per-cache, not
    /// per-request); `decode` must not touch this cache.
    pub fn get_or_insert_with<F>(&self, key: &str, decode: F) -> Arc<RuntimePlane>
    where
        F: FnOnce() -> RuntimePlane,
    {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        let now = inner.tick;
        if let Some(e) = inner.map.get_mut(key) {
            e.last_used = now;
            inner.stats.hits += 1;
            return e.plane.clone();
        }
        let plane = Arc::new(decode());
        // Charge the true resident size: codes + per-row codebooks —
        // NOT the f32 plane this entry can be dequantized into.
        let bytes = plane.memory_bytes();
        inner.stats.misses += 1;
        inner.stats.decoded_bytes += bytes as u64;
        inner.bytes += bytes;
        inner
            .map
            .insert(key.to_string(), Entry { plane: plane.clone(), bytes, last_used: now });
        // Evict LRU entries (never the one just inserted) until within
        // budget. A single over-budget plane stays resident — the cache
        // must still serve it.
        while inner.bytes > self.budget_bytes && inner.map.len() > 1 {
            let victim = inner
                .map
                .iter()
                .filter(|(k, _)| k.as_str() != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = inner.map.remove(&k).expect("victim vanished");
                    inner.bytes -= e.bytes;
                    inner.stats.evictions += 1;
                }
                None => break,
            }
        }
        plane
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes (≤ budget except for a single oversized plane).
    pub fn bytes_used(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Drop every resident plane (stats are preserved).
    pub fn clear(&self) {
        let mut guard = self.inner.lock().unwrap();
        guard.map.clear();
        guard.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::synthzoo;

    /// A synthetic runtime plane with an exactly-known byte footprint:
    /// `rows·⌈cols·(bits+1)/8⌉` packed code bytes +
    /// `rows · 2^(bits+1) · 4` codebook bytes.
    fn plane(rows: usize, cols: usize, seed: u64) -> RuntimePlane {
        let bits = 1u32;
        let codes: Vec<u8> = (0..rows * cols).map(|i| ((i as u64 ^ seed) % 4) as u8).collect();
        let codebooks: Vec<f32> =
            (0..rows).flat_map(|r| vec![r as f32; 1 << (bits + 1)]).collect();
        RuntimePlane::from_byte_codes(rows, cols, bits, &codes, codebooks)
    }

    /// plane(8, 224, _) → 8·⌈224·2/8⌉ + 8·4·4 = 448 + 128 = 576 bytes —
    /// the *packed* footprint (the v1 byte-code plane was 8·224 + 128 =
    /// 1920 bytes; a budget sized in packed bytes must be charged packed
    /// bytes, or eviction fires 3× early).
    const PLANE_BYTES: usize = 8 * 56 + 8 * 4 * 4;

    #[test]
    fn hit_returns_same_arc_and_counts() {
        let c = DecodeCache::new(1 << 20);
        let a = c.get_or_insert_with("x", || plane(8, 224, 1));
        let b = c.get_or_insert_with("x", || panic!("decode ran on a hit"));
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_used(), PLANE_BYTES);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn charges_packed_plane_bytes_not_f32_or_byte_codes() {
        // Regression (two generations of accounting bug): the entry must
        // be charged packed codes + codebooks — not the 4·rows·cols f32
        // plane, and not one byte per code either.
        let c = DecodeCache::new(1 << 20);
        let p = c.get_or_insert_with("p", || plane(8, 224, 3));
        assert_eq!(c.bytes_used(), p.memory_bytes());
        assert_eq!(c.bytes_used(), PLANE_BYTES);
        assert!(c.bytes_used() < p.rows * p.cols * 4, "charged like f32");
        assert!(c.bytes_used() < p.rows * p.cols, "charged like byte codes");
        assert_eq!(c.stats().decoded_bytes, p.memory_bytes() as u64);
    }

    #[test]
    fn eviction_regression_budget_fits_more_packed_planes() {
        // A budget that held exactly one v1 byte-code plane (1920 B)
        // holds three packed planes (576 B each) with room to spare —
        // the "~3× more layers resident at the same budget" claim, as an
        // eviction regression: under byte-code accounting the second and
        // third inserts would each evict.
        let byte_plane_bytes = 8 * 224 + 8 * 4 * 4;
        let c = DecodeCache::new(byte_plane_bytes);
        c.get_or_insert_with("a", || plane(8, 224, 1));
        c.get_or_insert_with("b", || plane(8, 224, 2));
        c.get_or_insert_with("c", || plane(8, 224, 3));
        assert_eq!(c.len(), 3, "three packed planes fit one byte-plane budget");
        assert_eq!(c.stats().evictions, 0);
        assert!(c.bytes_used() <= byte_plane_bytes);
    }

    #[test]
    fn eviction_triggers_at_runtime_byte_budget() {
        // Regression: budget sized in *runtime-plane* bytes. Two planes
        // fit exactly; under f32 accounting (≈3.7× larger) the second
        // insert would evict immediately and the third would not.
        let c = DecodeCache::new(2 * PLANE_BYTES);
        c.get_or_insert_with("a", || plane(8, 224, 1));
        c.get_or_insert_with("b", || plane(8, 224, 2));
        assert_eq!(c.len(), 2, "two planes must fit the two-plane budget");
        assert_eq!(c.stats().evictions, 0);
        // Touch "a" so "b" is the LRU victim when "c" arrives.
        c.get_or_insert_with("a", || panic!("hit expected"));
        c.get_or_insert_with("c", || plane(8, 224, 3));
        assert_eq!(c.len(), 2);
        assert!(c.bytes_used() <= 2 * PLANE_BYTES);
        assert_eq!(c.stats().evictions, 1);
        // "a" survived; "b" was the victim and re-decodes on re-fetch.
        c.get_or_insert_with("a", || panic!("'a' should still be resident"));
        let before = c.stats().misses;
        c.get_or_insert_with("b", || plane(8, 224, 2));
        assert_eq!(c.stats().misses, before + 1);
    }

    #[test]
    fn oversized_single_entry_stays_resident() {
        let c = DecodeCache::new(16); // absurdly small budget
        let a = c.get_or_insert_with("big", || plane(8, 224, 7));
        assert_eq!(c.len(), 1);
        let b = c.get_or_insert_with("big", || panic!("must hit"));
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn decode_cache_decodes_icq_matrices_once() {
        let w = synthzoo::demo_matrix(16, 256, 9);
        let q = crate::icquant::IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let c = DecodeCache::new(1 << 20);
        let d1 = c.get_or_decode("m", &q);
        let d2 = c.get_or_decode("m", &q);
        assert!(Arc::ptr_eq(&d1, &d2));
        let rt = q.to_runtime();
        assert_eq!(d1.packed(), rt.packed());
        assert_eq!(d1.dequantize().data, rt.dequantize().data);
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.bytes_used(), rt.memory_bytes());
    }

    #[test]
    fn clear_preserves_stats() {
        let c = DecodeCache::new(1 << 20);
        c.get_or_insert_with("a", || plane(8, 224, 1));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn shared_across_threads() {
        let c = Arc::new(DecodeCache::new(1 << 20));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..8 {
                    let _ = c.get_or_insert_with(&format!("k{}", i), || plane(8, 224, i as u64));
                }
                t
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 32);
        assert_eq!(c.len(), 8);
        assert_eq!(s.misses, 8);
    }
}

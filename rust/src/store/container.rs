//! `ICQZ` v1: the single-file multi-tensor container for a quantized
//! checkpoint.
//!
//! Layout (little-endian):
//! ```text
//! 0   magic   "ICQZ"                      4 B
//! 4   version u32                         4 B
//! 8   toc_len u32                         4 B
//! 12  toc_crc u32 (CRC32 of the TOC)      4 B
//! 16  toc     JSON                        toc_len B
//!     zero padding to a 64-byte boundary  → data_start
//!     sections, each starting 64-byte-aligned relative to data_start,
//!     zero padding between sections, file ends at the last section's
//!     final byte
//! ```
//!
//! The TOC records the [`ModelConfig`], exact bits/weight accounting
//! (`storage_bits_per_weight` is measured over the serialized section
//! bytes, not estimated), and one entry per section:
//! `{name, kind: "icq"|"f32", shape, offset, len, crc32}` with `offset`
//! relative to `data_start` — offsets are therefore independent of the
//! TOC's own length, and 64-byte alignment makes every section directly
//! mmap-able.
//!
//! Section payloads: `icq` sections embed the [`crate::icquant::packed`]
//! `ICQM` byte layout verbatim (one quantized matrix each); `f32`
//! sections are raw little-endian f32 data with the shape in the TOC.
//! Every byte of the file is covered by a check: magic/version by
//! [`load`]/[`verify`], the TOC by `toc_crc`, padding by the
//! all-zeros rule, and sections by their CRC32s — a single flipped byte
//! anywhere is detected by [`verify`].

use crate::icquant::{packed, IcqMatrix};
use crate::model::ModelConfig;
use crate::util::crc32;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 4] = b"ICQZ";
const VERSION: u32 = 1;
const ALIGN: usize = 64;
/// Fixed-size prefix before the TOC bytes.
const PREFIX: usize = 16;
/// Reads reject TOCs larger than this before allocating.
const MAX_TOC_LEN: usize = 1 << 24;
/// Sanity caps on untrusted TOC values: with offsets/lengths below
/// 2^40 and element counts below 2^34, every sum and `numel * 4`
/// downstream fits a u64/usize with room to spare — no read-path
/// arithmetic can wrap even on adversarial input.
const MAX_SECTION_BYTES: usize = 1 << 40;
const MAX_SECTION_ELEMS: usize = 1 << 34;

/// Checked product of an untrusted shape, capped at
/// [`MAX_SECTION_ELEMS`].
fn checked_numel(name: &str, shape: &[usize]) -> Result<usize> {
    let mut numel = 1usize;
    for &d in shape {
        numel = numel
            .checked_mul(d)
            .filter(|&n| n <= MAX_SECTION_ELEMS)
            .with_context(|| {
                format!("section '{}': implausible shape {:?}", name, shape)
            })?;
    }
    Ok(numel)
}

fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

/// One tensor going into (or coming out of) a container.
pub enum TensorPayload {
    /// A quantized projection (stored as an embedded `ICQM` payload).
    Quantized(IcqMatrix),
    /// An f32 side tensor (norms, embeddings, heads).
    Dense { shape: Vec<usize>, data: Vec<f32> },
}

/// An in-memory model checkpoint: ordered named tensors + config. Order
/// is load-bearing (the positional ABI of the AOT-compiled HLO entries).
pub struct IcqzModel {
    pub config: Option<ModelConfig>,
    /// NaN when unknown (synthetic checkpoints).
    pub val_loss: f64,
    pub entries: Vec<(String, TensorPayload)>,
}

/// Which payload codec a section uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionKind {
    Icq,
    F32,
}

impl SectionKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SectionKind::Icq => "icq",
            SectionKind::F32 => "f32",
        }
    }

    fn parse(s: &str) -> Result<SectionKind> {
        match s {
            "icq" => Ok(SectionKind::Icq),
            "f32" => Ok(SectionKind::F32),
            other => bail!("unknown section kind '{}'", other),
        }
    }
}

/// TOC entry for one section.
#[derive(Clone, Debug)]
pub struct SectionInfo {
    pub name: String,
    pub kind: SectionKind,
    pub shape: Vec<usize>,
    /// Byte offset relative to `data_start` (64-byte aligned).
    pub offset: usize,
    pub len: usize,
    pub crc32: u32,
}

/// Parsed header + TOC of a container (no payload decode).
#[derive(Clone, Debug)]
pub struct ContainerInfo {
    pub config: Option<ModelConfig>,
    pub val_loss: f64,
    pub sections: Vec<SectionInfo>,
    pub quantized_params: usize,
    pub dense_params: usize,
    /// Measured: Σ `icq` section bytes × 8 / quantized params. Exact by
    /// construction — this *is* the paper's deployed-size claim.
    pub storage_bits_per_weight: f64,
    /// Σ (n + B) · numel / Σ numel over quantized layers (code planes +
    /// index streams, the paper's headline accounting).
    pub code_bits_per_weight: f64,
    /// `code_bits_per_weight` + codebook storage.
    pub full_bits_per_weight: f64,
    pub data_start: usize,
    pub file_len: u64,
}

impl ContainerInfo {
    pub fn section(&self, name: &str) -> Option<&SectionInfo> {
        self.sections.iter().find(|s| s.name == name)
    }
}

/// Outcome of a full-file integrity check. `issues` is empty iff every
/// byte of the file verified clean.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub sections_checked: usize,
    pub bytes_checked: u64,
    pub issues: Vec<String>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.issues.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Write path
// ---------------------------------------------------------------------------

struct Plan {
    toc: String,
    data_start: usize,
    sections: Vec<SectionInfo>,
    payloads: Vec<Vec<u8>>,
    total: usize,
}

fn payload_bytes(name: &str, payload: &TensorPayload) -> Result<(SectionKind, Vec<usize>, Vec<u8>)> {
    match payload {
        TensorPayload::Quantized(m) => {
            Ok((SectionKind::Icq, vec![m.rows, m.cols], packed::to_bytes(m)))
        }
        TensorPayload::Dense { shape, data } => {
            let numel: usize = shape.iter().product();
            ensure!(
                numel == data.len(),
                "tensor '{}': shape {:?} does not match {} values",
                name,
                shape,
                data.len()
            );
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for x in data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            Ok((SectionKind::F32, shape.clone(), bytes))
        }
    }
}

fn plan(model: &IcqzModel) -> Result<Plan> {
    let mut sections = Vec::with_capacity(model.entries.len());
    let mut payloads = Vec::with_capacity(model.entries.len());
    let mut offset = 0usize;
    let mut quantized_params = 0usize;
    let mut dense_params = 0usize;
    let mut storage_bits = 0u64;
    let mut code_bits = 0.0f64;
    let mut full_bits = 0.0f64;
    for (name, payload) in &model.entries {
        ensure!(!name.is_empty(), "empty tensor name");
        ensure!(
            !sections.iter().any(|s: &SectionInfo| &s.name == name),
            "duplicate tensor name '{}'",
            name
        );
        let (kind, shape, bytes) = payload_bytes(name, payload)?;
        if let TensorPayload::Quantized(m) = payload {
            let numel = m.rows * m.cols;
            quantized_params += numel;
            storage_bits += bytes.len() as u64 * 8;
            code_bits += m.avg_bits_per_weight() * numel as f64;
            full_bits += m.avg_bits_per_weight_full() * numel as f64;
        } else {
            dense_params += shape.iter().product::<usize>();
        }
        sections.push(SectionInfo {
            name: name.clone(),
            kind,
            shape,
            offset,
            len: bytes.len(),
            crc32: crc32(&bytes),
        });
        offset = align_up(offset + bytes.len());
        payloads.push(bytes);
    }
    let data_span = sections.last().map(|s| s.offset + s.len).unwrap_or(0);

    let per_weight = |total: f64| {
        if quantized_params == 0 {
            0.0
        } else {
            total / quantized_params as f64
        }
    };
    let mut toc_fields = vec![
        ("format", Json::str("icqz")),
        ("version", Json::num(VERSION as f64)),
        (
            "config",
            match &model.config {
                Some(c) => c.to_json(),
                None => Json::Null,
            },
        ),
        ("quantized_params", Json::num(quantized_params as f64)),
        ("dense_params", Json::num(dense_params as f64)),
        ("storage_bits_per_weight", Json::num(per_weight(storage_bits as f64))),
        ("code_bits_per_weight", Json::num(per_weight(code_bits))),
        ("full_bits_per_weight", Json::num(per_weight(full_bits))),
        (
            "sections",
            Json::arr(
                sections
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::str(s.name.clone())),
                            ("kind", Json::str(s.kind.as_str())),
                            (
                                "shape",
                                Json::arr(
                                    s.shape.iter().map(|&d| Json::num(d as f64)).collect(),
                                ),
                            ),
                            ("offset", Json::num(s.offset as f64)),
                            ("len", Json::num(s.len as f64)),
                            ("crc32", Json::num(s.crc32 as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    // NaN is not representable in JSON; only persist a known loss.
    if model.val_loss.is_finite() {
        toc_fields.push(("val_loss", Json::num(model.val_loss)));
    }
    let toc = Json::obj(toc_fields).to_string();
    ensure!(toc.len() <= MAX_TOC_LEN, "TOC too large ({} bytes)", toc.len());
    let data_start = align_up(PREFIX + toc.len());
    // A sectionless container ends right after the TOC (no pad to write).
    let total = if sections.is_empty() {
        PREFIX + toc.len()
    } else {
        data_start + data_span
    };
    Ok(Plan { toc, data_start, sections, payloads, total })
}

/// Exact on-disk size in bytes of `container::save(model)`.
pub fn serialized_size(model: &IcqzModel) -> Result<usize> {
    Ok(plan(model)?.total)
}

/// Write a single-file `ICQZ` container.
pub fn save(model: &IcqzModel, path: &Path) -> Result<()> {
    let p = plan(model)?;
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(p.toc.len() as u32).to_le_bytes())?;
    f.write_all(&crc32(p.toc.as_bytes()).to_le_bytes())?;
    f.write_all(p.toc.as_bytes())?;
    let mut pos = PREFIX + p.toc.len();
    for (meta, bytes) in p.sections.iter().zip(&p.payloads) {
        let target = p.data_start + meta.offset;
        debug_assert!(target >= pos);
        write_zeros(&mut f, target - pos)?;
        f.write_all(bytes)?;
        pos = target + bytes.len();
    }
    debug_assert_eq!(pos, p.total);
    f.flush()?;
    Ok(())
}

fn write_zeros<W: Write>(f: &mut W, n: usize) -> std::io::Result<()> {
    const Z: [u8; ALIGN] = [0u8; ALIGN];
    let mut left = n;
    while left > 0 {
        let take = left.min(ALIGN);
        f.write_all(&Z[..take])?;
        left -= take;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Read path
// ---------------------------------------------------------------------------

fn parse_sections(toc: &Json) -> Result<Vec<SectionInfo>> {
    let arr = toc.req("sections")?.as_arr().context("sections not an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, s) in arr.iter().enumerate() {
        let name = s.req("name")?.as_str().context("section name")?.to_string();
        let kind = SectionKind::parse(s.req("kind")?.as_str().context("section kind")?)?;
        let shape: Vec<usize> = s
            .req("shape")?
            .as_arr()
            .context("section shape")?
            .iter()
            .map(|d| d.as_usize().context("shape element"))
            .collect::<Result<_>>()?;
        let offset = s.req("offset")?.as_usize().context("section offset")?;
        let len = s.req("len")?.as_usize().context("section len")?;
        let crc = s.req("crc32")?.as_usize().context("section crc32")?;
        ensure!(crc <= u32::MAX as usize, "section {} crc32 out of range", i);
        ensure!(
            offset <= MAX_SECTION_BYTES && len <= MAX_SECTION_BYTES,
            "section '{}': implausible offset {} / len {}",
            name,
            offset,
            len
        );
        let numel = checked_numel(&name, &shape)?;
        if kind == SectionKind::F32 {
            ensure!(
                len == numel * 4,
                "section '{}': {} bytes for shape {:?}",
                name,
                len,
                shape
            );
        }
        ensure!(offset % ALIGN == 0, "section '{}' offset {} not {}-aligned", name, offset, ALIGN);
        ensure!(
            out.iter().all(|p: &SectionInfo| p.name != name),
            "duplicate section name '{}'",
            name
        );
        if let Some(prev) = out.last() {
            ensure!(
                offset >= align_up(prev.offset + prev.len),
                "section '{}' overlaps its predecessor",
                name
            );
        }
        out.push(SectionInfo { name, kind, shape, offset, len, crc32: crc as u32 });
    }
    Ok(out)
}

fn read_header(bytes: &[u8], check_toc_crc: bool) -> Result<(Json, usize)> {
    ensure!(bytes.len() >= PREFIX, "file too short for an ICQZ header");
    ensure!(bytes[0..4] == MAGIC[..], "not an ICQZ container: bad magic");
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    ensure!(version == VERSION, "unsupported ICQZ version {}", version);
    let toc_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    ensure!(toc_len <= MAX_TOC_LEN, "TOC length {} exceeds cap", toc_len);
    ensure!(PREFIX + toc_len <= bytes.len(), "TOC extends past end of file");
    let toc_crc = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let toc_bytes = &bytes[PREFIX..PREFIX + toc_len];
    if check_toc_crc {
        ensure!(
            crc32(toc_bytes) == toc_crc,
            "TOC checksum mismatch (file header corrupt?)"
        );
    }
    let toc = Json::parse(std::str::from_utf8(toc_bytes).context("TOC not utf-8")?)
        .map_err(|e| anyhow::anyhow!("TOC: {}", e))?;
    Ok((toc, align_up(PREFIX + toc_len)))
}

fn info_from_toc(toc: &Json, data_start: usize, file_len: u64) -> Result<ContainerInfo> {
    let config = match toc.req("config")? {
        Json::Null => None,
        c => Some(ModelConfig::from_json(c)?),
    };
    let val_loss = toc.get("val_loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    Ok(ContainerInfo {
        config,
        val_loss,
        sections: parse_sections(toc)?,
        quantized_params: toc.req("quantized_params")?.as_usize().context("quantized_params")?,
        dense_params: toc.req("dense_params")?.as_usize().context("dense_params")?,
        storage_bits_per_weight: toc
            .req("storage_bits_per_weight")?
            .as_f64()
            .context("storage_bits_per_weight")?,
        code_bits_per_weight: toc
            .req("code_bits_per_weight")?
            .as_f64()
            .context("code_bits_per_weight")?,
        full_bits_per_weight: toc
            .req("full_bits_per_weight")?
            .as_f64()
            .context("full_bits_per_weight")?,
        data_start,
        file_len,
    })
}

/// Parse header + TOC only (cheap; no payload reads or checksums beyond
/// the TOC's own CRC).
pub fn inspect(path: &Path) -> Result<ContainerInfo> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    inspect_bytes(&bytes)
}

/// [`inspect`] over an already-read buffer (lets callers that also hash
/// or store the container — e.g. the registry — read the file once, so
/// the bytes validated are exactly the bytes kept).
pub fn inspect_bytes(bytes: &[u8]) -> Result<ContainerInfo> {
    let (toc, data_start) = read_header(bytes, true)?;
    let info = info_from_toc(&toc, data_start, bytes.len() as u64)?;
    if let Some(last) = info.sections.last() {
        ensure!(
            data_start + last.offset + last.len <= bytes.len(),
            "sections extend past end of file"
        );
    }
    Ok(info)
}

/// Load the full model: every section checksum is verified and every
/// payload decoded (through the hardened `ICQM` reader for `icq`
/// sections).
pub fn load(path: &Path) -> Result<IcqzModel> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    let (toc, data_start) = read_header(&bytes, true)?;
    let info = info_from_toc(&toc, data_start, bytes.len() as u64)?;
    let mut entries = Vec::with_capacity(info.sections.len());
    for s in &info.sections {
        let start = data_start + s.offset;
        ensure!(
            start + s.len <= bytes.len(),
            "section '{}' extends past end of file",
            s.name
        );
        let payload = &bytes[start..start + s.len];
        ensure!(
            crc32(payload) == s.crc32,
            "section '{}' checksum mismatch (corrupt container)",
            s.name
        );
        let value = match s.kind {
            SectionKind::Icq => {
                let m = packed::from_bytes(payload)
                    .with_context(|| format!("section '{}'", s.name))?;
                ensure!(
                    s.shape == [m.rows, m.cols],
                    "section '{}': TOC shape {:?} != payload dims [{}, {}]",
                    s.name,
                    s.shape,
                    m.rows,
                    m.cols
                );
                TensorPayload::Quantized(m)
            }
            SectionKind::F32 => {
                // `len == numel(shape) * 4` was validated (with checked
                // arithmetic) when the TOC was parsed.
                let data: Vec<f32> = payload
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                TensorPayload::Dense { shape: s.shape.clone(), data }
            }
        };
        entries.push((s.name.clone(), value));
    }
    Ok(IcqzModel { config: info.config, val_loss: info.val_loss, entries })
}

/// Full-file integrity check. Collects *all* problems instead of failing
/// fast; together the checks cover every byte of the file (header, TOC
/// CRC, zero padding, per-section CRCs, exact file length), so any
/// single flipped byte surfaces as at least one issue.
pub fn verify(path: &Path) -> Result<VerifyReport> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    Ok(verify_bytes(&bytes))
}

/// [`verify`] over an already-read buffer (lets callers that also hash
/// the container — e.g. the registry — read the file once).
pub fn verify_bytes(bytes: &[u8]) -> VerifyReport {
    let mut report = VerifyReport { bytes_checked: bytes.len() as u64, ..Default::default() };
    let (toc, data_start) = match read_header(bytes, false) {
        Ok(x) => x,
        Err(e) => {
            report.issues.push(format!("header: {:#}", e));
            return report;
        }
    };
    // TOC CRC (header parse above skipped it so we can report it here).
    let toc_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let stored_crc = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    if crc32(&bytes[PREFIX..PREFIX + toc_len]) != stored_crc {
        report.issues.push("TOC checksum mismatch".to_string());
    }
    let info = match info_from_toc(&toc, data_start, bytes.len() as u64) {
        Ok(i) => i,
        Err(e) => {
            report.issues.push(format!("TOC: {:#}", e));
            return report;
        }
    };
    // Padding between TOC end and data_start must be zero.
    let mut covered = PREFIX + toc_len;
    let check_pad = |report: &mut VerifyReport, from: usize, to: usize, what: &str| {
        if from >= to || to > bytes.len() {
            return;
        }
        if bytes[from..to].iter().any(|&b| b != 0) {
            report.issues.push(format!("nonzero padding bytes {} ({}..{})", what, from, to));
        }
    };
    for s in &info.sections {
        let start = data_start + s.offset;
        let end = start + s.len;
        if end > bytes.len() {
            report.issues.push(format!("section '{}' extends past end of file", s.name));
            continue;
        }
        check_pad(&mut report, covered, start, &format!("before '{}'", s.name));
        let payload = &bytes[start..end];
        if crc32(payload) != s.crc32 {
            report.issues.push(format!("section '{}' checksum mismatch", s.name));
        } else if s.kind == SectionKind::Icq {
            match packed::from_bytes(payload) {
                Ok(m) => {
                    if s.shape != [m.rows, m.cols] {
                        report.issues.push(format!(
                            "section '{}': TOC shape {:?} != payload dims [{}, {}]",
                            s.name, s.shape, m.rows, m.cols
                        ));
                    }
                }
                Err(e) => report
                    .issues
                    .push(format!("section '{}' undecodable: {:#}", s.name, e)),
            }
        }
        report.sections_checked += 1;
        covered = end;
    }
    // The file must end exactly at the last section (no trailing bytes).
    if covered != bytes.len() {
        report.issues.push(format!(
            "file length {} != expected {} (trailing or missing bytes)",
            bytes.len(),
            covered
        ));
    }
    // Measured accounting must match the header claim exactly.
    let measured: u64 = info
        .sections
        .iter()
        .filter(|s| s.kind == SectionKind::Icq)
        .map(|s| s.len as u64 * 8)
        .sum();
    if info.quantized_params > 0 {
        let bpw = measured as f64 / info.quantized_params as f64;
        if (bpw - info.storage_bits_per_weight).abs() > 1e-9 {
            report.issues.push(format!(
                "header claims {} bits/weight, sections measure {}",
                info.storage_bits_per_weight, bpw
            ));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::quant::QuantizerKind;
    use crate::store;
    use crate::synthzoo;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("icqz_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn demo_model() -> IcqzModel {
        let f = synthzoo::family("llama3.2-1b").unwrap();
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        store::synth_model(&f, &cfg, Some(1)).unwrap()
    }

    #[test]
    fn save_load_preserves_everything() {
        let model = demo_model();
        let p = tmp("roundtrip.icqz");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.entries.len(), model.entries.len());
        let cfg = back.config.as_ref().unwrap();
        assert_eq!(cfg.d_model, model.config.as_ref().unwrap().d_model);
        for ((n1, p1), (n2, p2)) in model.entries.iter().zip(&back.entries) {
            assert_eq!(n1, n2);
            match (p1, p2) {
                (TensorPayload::Dense { data: a, .. }, TensorPayload::Dense { data: b, .. }) => {
                    assert_eq!(a, b, "{}", n1);
                }
                (TensorPayload::Quantized(a), TensorPayload::Quantized(b)) => {
                    assert_eq!(a.code_plane.bytes(), b.code_plane.bytes(), "{}", n1);
                    for r in 0..a.rows {
                        assert_eq!(a.index_codes[r].decode(), b.index_codes[r].decode());
                    }
                }
                _ => panic!("{}: payload kind changed", n1),
            }
        }
    }

    #[test]
    fn serialized_size_is_exact_and_sections_aligned() {
        let model = demo_model();
        let p = tmp("size.icqz");
        save(&model, &p).unwrap();
        let actual = std::fs::metadata(&p).unwrap().len() as usize;
        assert_eq!(actual, serialized_size(&model).unwrap());
        let info = inspect(&p).unwrap();
        assert_eq!(info.data_start % ALIGN, 0);
        for s in &info.sections {
            assert_eq!(s.offset % ALIGN, 0, "section {} misaligned", s.name);
        }
    }

    #[test]
    fn header_accounting_is_exact() {
        let model = demo_model();
        let p = tmp("accounting.icqz");
        save(&model, &p).unwrap();
        let info = inspect(&p).unwrap();
        // Measured over the file's sections…
        let mut measured_bits = 0u64;
        let mut params = 0usize;
        for s in &info.sections {
            if s.kind == SectionKind::Icq {
                measured_bits += s.len as u64 * 8;
                params += s.shape.iter().product::<usize>();
            }
        }
        assert_eq!(params, info.quantized_params);
        let measured = measured_bits as f64 / params as f64;
        assert!(
            (measured - info.storage_bits_per_weight).abs() < 1e-9,
            "header {} vs file-measured {}",
            info.storage_bits_per_weight,
            measured
        );
        // …and over the in-memory matrices: the container must cost
        // exactly what the per-matrix `IcqMatrix::storage_bytes`
        // accounting claims (well within the 1% acceptance envelope —
        // it is identical by construction).
        let mut mem_bits = 0u64;
        let mut code_bits = 0.0;
        for (_, payload) in &model.entries {
            if let TensorPayload::Quantized(m) = payload {
                mem_bits += m.storage_bytes() as u64 * 8;
                code_bits += m.avg_bits_per_weight() * (m.rows * m.cols) as f64;
            }
        }
        let mem = mem_bits as f64 / params as f64;
        assert!(
            (mem - info.storage_bits_per_weight).abs() < 1e-9,
            "header {} vs IcqMatrix accounting {}",
            info.storage_bits_per_weight,
            mem
        );
        assert!((code_bits / params as f64 - info.code_bits_per_weight).abs() < 1e-9);
        // Container framing (TOC + alignment padding + dense sections
        // aside) adds < 1% on top of the summed section payloads.
        let section_bits: u64 =
            info.sections.iter().map(|s| s.len as u64 * 8).sum();
        let file_bits = info.file_len * 8;
        assert!(
            (file_bits as f64) < section_bits as f64 * 1.01,
            "container framing overhead too large: {} vs {}",
            file_bits,
            section_bits
        );
        // Storage ≥ code accounting (headers/codebooks ride on top) and
        // in the paper's ≈(n+0.3) neighborhood for 2-bit γ=5 %.
        assert!(info.storage_bits_per_weight > info.code_bits_per_weight);
        assert!(info.code_bits_per_weight > 2.0 && info.code_bits_per_weight < 2.5);
    }

    #[test]
    fn verify_clean_file_is_ok() {
        let model = demo_model();
        let p = tmp("verify_ok.icqz");
        save(&model, &p).unwrap();
        let report = verify(&p).unwrap();
        assert!(report.ok(), "issues: {:?}", report.issues);
        assert_eq!(report.sections_checked, model.entries.len());
    }

    #[test]
    fn verify_detects_any_single_flipped_byte() {
        let model = demo_model();
        let p = tmp("verify_flip.icqz");
        save(&model, &p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        // A sample stride through the whole file plus the structural
        // boundaries — each flip must surface as at least one issue.
        let info = inspect(&p).unwrap();
        let mut offsets: Vec<usize> = (0..clean.len()).step_by(509).collect();
        offsets.extend([0, 5, 9, 13, 20, clean.len() - 1]);
        for s in &info.sections {
            // First payload byte, and the padding byte right before it.
            offsets.push(info.data_start + s.offset);
            if s.offset > 0 {
                offsets.push(info.data_start + s.offset - 1);
            }
        }
        for off in offsets {
            let mut corrupt = clean.clone();
            corrupt[off] ^= 0x40;
            let pc = tmp("verify_flip_corrupt.icqz");
            std::fs::write(&pc, &corrupt).unwrap();
            let report = verify(&pc).unwrap();
            assert!(
                !report.ok(),
                "flip at byte {} of {} not detected",
                off,
                clean.len()
            );
        }
    }

    #[test]
    fn load_rejects_corrupt_sections() {
        let model = demo_model();
        let p = tmp("load_corrupt.icqz");
        save(&model, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let info = inspect(&p).unwrap();
        // Flip one byte inside the first icq section's payload.
        let s = info.sections.iter().find(|s| s.kind == SectionKind::Icq).unwrap();
        let off = info.data_start + s.offset + s.len / 2;
        bytes[off] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err();
        assert!(format!("{:#}", err).contains("checksum"), "{:#}", err);
    }

    #[test]
    fn empty_and_configless_models_round_trip() {
        let model = IcqzModel { config: None, val_loss: f64::NAN, entries: vec![] };
        let p = tmp("empty.icqz");
        save(&model, &p).unwrap();
        let back = load(&p).unwrap();
        assert!(back.config.is_none());
        assert!(back.entries.is_empty());
        assert!(back.val_loss.is_nan());
        assert!(verify(&p).unwrap().ok());
        assert_eq!(
            std::fs::metadata(&p).unwrap().len() as usize,
            serialized_size(&model).unwrap()
        );
    }

    #[test]
    fn duplicate_names_rejected_at_save() {
        let model = IcqzModel {
            config: None,
            val_loss: f64::NAN,
            entries: vec![
                ("a".into(), TensorPayload::Dense { shape: vec![1], data: vec![1.0] }),
                ("a".into(), TensorPayload::Dense { shape: vec![1], data: vec![2.0] }),
            ],
        };
        assert!(save(&model, &tmp("dup.icqz")).is_err());
    }
}

//! On-disk artifact registry: content-hash-named container files plus a
//! manifest, so consumers resolve quantized checkpoints by `name@hash`
//! instead of ad-hoc paths.
//!
//! Layout under the registry root (`$ICQ_STORE`, default `icq_store/`):
//! ```text
//! icq_store/
//!   manifest.json            {"artifacts": [{name, hash, bytes, ...}]}
//!   objects/<hash>.icqz      immutable, content-addressed containers
//! ```
//!
//! The hash is a 128-bit FNV-1a variant (two independent 64-bit
//! streams), hex-encoded — content *addressing* and corruption
//! detection, not cryptographic authentication (the offline registry
//! carries no hash crates; collisions under non-adversarial use are
//! vanishingly unlikely and `verify` additionally re-checks the
//! container's per-section CRCs).
//!
//! `put` is atomic (write to a temp file, then rename), `objects/` files
//! are deduplicated by hash, and `gc` removes objects no manifest entry
//! references (e.g. after a manifest edit or a crashed `put`).

use super::container;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// One manifest row. `name` is the human handle; `hash` the immutable id.
#[derive(Clone, Debug)]
pub struct ArtifactRecord {
    pub name: String,
    pub hash: String,
    pub bytes: u64,
    pub storage_bits_per_weight: f64,
    pub created_unix: u64,
}

impl ArtifactRecord {
    /// `name@hash12` — the canonical display form.
    pub fn spec(&self) -> String {
        format!("{}@{}", self.name, &self.hash[..12.min(self.hash.len())])
    }
}

/// Handle to a registry root directory.
pub struct Registry {
    root: PathBuf,
}

/// Exclusive advisory lock over the registry's mutating operations:
/// a lock file created with `O_EXCL`, removed on drop. `put` and `gc`
/// are read-modify-write over `manifest.json` / `objects/`; without
/// this, two concurrent puts would silently drop one record (and a
/// racing gc could delete a just-copied object). Readers don't need
/// it — manifest writes are atomic renames.
struct RegistryLock {
    path: PathBuf,
}

impl RegistryLock {
    fn acquire(root: &Path) -> Result<RegistryLock> {
        let path = root.join("registry.lock");
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(_) => return Ok(RegistryLock { path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    ensure!(
                        std::time::Instant::now() < deadline,
                        "timed out waiting for registry lock {} (crashed holder? remove it)",
                        path.display()
                    );
                    std::thread::sleep(std::time::Duration::from_millis(25));
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("create lock {}", path.display()))
                }
            }
        }
    }
}

impl Drop for RegistryLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Seconds since a file was last modified (None if the filesystem
/// can't say — such files are never gc'd).
fn entry_age_secs(path: &Path) -> Option<u64> {
    let modified = std::fs::metadata(path).ok()?.modified().ok()?;
    std::time::SystemTime::now().duration_since(modified).ok().map(|d| d.as_secs())
}

/// 128-bit FNV-1a-style content hash, hex-encoded (see module docs).
pub fn content_hash(bytes: &[u8]) -> String {
    const OFFSET_A: u64 = 0xcbf2_9ce4_8422_2325;
    const OFFSET_B: u64 = 0x6c62_272e_07bb_0142;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut a = OFFSET_A;
    let mut b = OFFSET_B;
    for &x in bytes {
        a = (a ^ x as u64).wrapping_mul(PRIME);
        b = (b ^ (x ^ 0x5c) as u64).wrapping_mul(PRIME);
    }
    // Finalize with a length fold so prefixes don't collide trivially.
    a ^= (bytes.len() as u64).wrapping_mul(PRIME);
    b = (b ^ a.rotate_left(29)).wrapping_mul(PRIME);
    format!("{:016x}{:016x}", a, b)
}

impl Registry {
    /// Open (creating directories if needed) a registry at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<Registry> {
        let root = root.into();
        std::fs::create_dir_all(root.join("objects"))
            .with_context(|| format!("create registry at {}", root.display()))?;
        Ok(Registry { root })
    }

    /// `$ICQ_STORE` or `./icq_store`.
    pub fn default_root() -> PathBuf {
        std::env::var("ICQ_STORE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("icq_store"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    fn object_path(&self, hash: &str) -> PathBuf {
        self.root.join("objects").join(format!("{}.icqz", hash))
    }

    fn read_manifest(&self) -> Result<Vec<ArtifactRecord>> {
        let path = self.manifest_path();
        if !path.exists() {
            return Ok(Vec::new());
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {}", e))?;
        let mut out = Vec::new();
        for a in j.req("artifacts")?.as_arr().context("artifacts not an array")? {
            out.push(ArtifactRecord {
                name: a.req("name")?.as_str().context("name")?.to_string(),
                hash: a.req("hash")?.as_str().context("hash")?.to_string(),
                bytes: a.req("bytes")?.as_usize().context("bytes")? as u64,
                storage_bits_per_weight: a
                    .req("storage_bits_per_weight")?
                    .as_f64()
                    .context("storage_bits_per_weight")?,
                created_unix: a.req("created_unix")?.as_usize().context("created_unix")?
                    as u64,
            });
        }
        Ok(out)
    }

    fn write_manifest(&self, records: &[ArtifactRecord]) -> Result<()> {
        let j = Json::obj(vec![(
            "artifacts",
            Json::arr(
                records
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::str(r.name.clone())),
                            ("hash", Json::str(r.hash.clone())),
                            ("bytes", Json::num(r.bytes as f64)),
                            (
                                "storage_bits_per_weight",
                                Json::num(r.storage_bits_per_weight),
                            ),
                            ("created_unix", Json::num(r.created_unix as f64)),
                        ])
                    })
                    .collect(),
            ),
        )]);
        let tmp = self.manifest_path().with_extension("json.tmp");
        std::fs::write(&tmp, j.to_string())
            .with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, self.manifest_path()).context("commit manifest")?;
        Ok(())
    }

    /// Register an existing `ICQZ` file under `name`: content-hash it,
    /// copy into `objects/`, append to the manifest. Re-putting identical
    /// content under the same name is a no-op returning the prior record.
    pub fn put_file(&self, name: &str, src: &Path) -> Result<ArtifactRecord> {
        ensure!(
            !name.is_empty() && !name.contains('@') && !name.contains('/'),
            "artifact name '{}' must be nonempty without '@' or '/'",
            name
        );
        // One read: the bytes we validate are exactly the bytes we hash
        // and store (no inspect-then-reread race with a writer of src).
        let bytes = std::fs::read(src)?;
        let info = container::inspect_bytes(&bytes)
            .with_context(|| format!("{} is not a readable ICQZ container", src.display()))?;
        let hash = content_hash(&bytes);
        // Object copy + manifest append must be atomic w.r.t. other
        // put/gc calls (see RegistryLock).
        let _lock = RegistryLock::acquire(&self.root)?;
        let mut records = self.read_manifest()?;
        if let Some(existing) = records.iter().find(|r| r.name == name && r.hash == hash) {
            return Ok(existing.clone());
        }
        let obj = self.object_path(&hash);
        if !obj.exists() {
            let tmp = obj.with_extension("icqz.tmp");
            std::fs::write(&tmp, &bytes)
                .with_context(|| format!("write {}", tmp.display()))?;
            std::fs::rename(&tmp, &obj).context("commit object")?;
        }
        let record = ArtifactRecord {
            name: name.to_string(),
            hash,
            bytes: bytes.len() as u64,
            storage_bits_per_weight: info.storage_bits_per_weight,
            created_unix: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        };
        records.push(record.clone());
        self.write_manifest(&records)?;
        Ok(record)
    }

    /// Serialize an in-memory model straight into the registry.
    ///
    /// # Examples
    ///
    /// The pack → resolve flow the CLI (`icquant pack --name …`) and the
    /// serving stack ride on:
    ///
    /// ```
    /// use icquant::icquant::IcqConfig;
    /// use icquant::store::{synth_model, Registry};
    ///
    /// let root = std::env::temp_dir()
    ///     .join(format!("icq_registry_doctest_{}", std::process::id()));
    /// let _ = std::fs::remove_dir_all(&root);
    /// let reg = Registry::open(&root).unwrap();
    ///
    /// // Quantize a one-block zoo model and register it under a name.
    /// let family = icquant::synthzoo::family("llama3.2-1b").unwrap();
    /// let model = synth_model(&family, &IcqConfig::default(), Some(1)).unwrap();
    /// let record = reg.put_model("demo", &model).unwrap();
    ///
    /// // Consumers get it back by name (newest) or name@hashprefix.
    /// let (rec, path) = reg.resolve("demo").unwrap();
    /// assert_eq!(rec.spec(), record.spec());
    /// assert!(path.exists());
    /// let (rec2, _) = reg.resolve(&record.spec()).unwrap();
    /// assert_eq!(rec2.hash, record.hash);
    /// # let _ = std::fs::remove_dir_all(&root);
    /// ```
    pub fn put_model(&self, name: &str, model: &container::IcqzModel) -> Result<ArtifactRecord> {
        // Unique temp name so concurrent puts of the same model name
        // never interleave writes into one file.
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let tmp = self
            .root
            .join(format!(".put-{}-{}-{}.icqz.tmp", name, std::process::id(), nanos));
        container::save(model, &tmp)?;
        let result = self.put_file(name, &tmp);
        let _ = std::fs::remove_file(&tmp);
        result
    }

    /// Resolve `"name"` (newest) or `"name@hashprefix"` to its record
    /// and object path.
    pub fn resolve(&self, spec: &str) -> Result<(ArtifactRecord, PathBuf)> {
        let records = self.read_manifest()?;
        let (name, prefix) = match spec.split_once('@') {
            Some((n, p)) => (n, Some(p)),
            None => (spec, None),
        };
        let matches: Vec<&ArtifactRecord> = records
            .iter()
            .filter(|r| {
                r.name == name
                    && match prefix {
                        Some(p) => r.hash.starts_with(p),
                        None => true,
                    }
            })
            .collect();
        let record = match (matches.last(), prefix) {
            (Some(&r), _) => r.clone(),
            (None, Some(p)) => bail!("no artifact '{}' with hash prefix '{}'", name, p),
            (None, None) => bail!(
                "no artifact named '{}' in registry {}",
                name,
                self.root.display()
            ),
        };
        if let Some(p) = prefix {
            let distinct: std::collections::HashSet<&str> =
                matches.iter().map(|r| r.hash.as_str()).collect();
            ensure!(
                distinct.len() == 1,
                "hash prefix '{}' is ambiguous for '{}' ({} matches)",
                p,
                name,
                distinct.len()
            );
        }
        let path = self.object_path(&record.hash);
        ensure!(
            path.exists(),
            "manifest references missing object {} (registry corrupted?)",
            path.display()
        );
        Ok((record, path))
    }

    /// All manifest records, oldest first.
    pub fn list(&self) -> Result<Vec<ArtifactRecord>> {
        self.read_manifest()
    }

    /// Integrity check for one artifact: the object's bytes must hash to
    /// its manifest id *and* pass the container's full-file verify. The
    /// file is read once; both checks run over the same buffer.
    pub fn verify(&self, spec: &str) -> Result<container::VerifyReport> {
        let (record, path) = self.resolve(spec)?;
        let bytes = std::fs::read(&path)?;
        let mut report = container::verify_bytes(&bytes);
        if content_hash(&bytes) != record.hash {
            report
                .issues
                .push(format!("object bytes no longer hash to {}", record.hash));
        }
        Ok(report)
    }

    /// Delete objects no manifest record references, plus stale put
    /// debris; returns the removed paths.
    pub fn gc(&self) -> Result<Vec<PathBuf>> {
        let _lock = RegistryLock::acquire(&self.root)?;
        let referenced: std::collections::HashSet<String> =
            self.read_manifest()?.into_iter().map(|r| r.hash).collect();
        let mut removed = Vec::new();
        for entry in std::fs::read_dir(self.root.join("objects"))? {
            let path = entry?.path();
            let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("");
            let ext = path.extension().and_then(|e| e.to_str());
            let stale = match ext {
                Some("icqz") => !referenced.contains(stem),
                Some("tmp") => true, // leftover from a crashed object copy
                _ => false,
            };
            if stale {
                std::fs::remove_file(&path)
                    .with_context(|| format!("remove {}", path.display()))?;
                removed.push(path);
            }
        }
        // A leftover `manifest.json.tmp` means a `write_manifest` died
        // between write and rename. Manifest writes only happen under
        // the registry lock — which gc holds right now — so any temp
        // present here is definitionally crash debris, no age gate.
        let manifest_tmp = self.manifest_path().with_extension("json.tmp");
        if manifest_tmp.is_file() {
            std::fs::remove_file(&manifest_tmp)
                .with_context(|| format!("remove {}", manifest_tmp.display()))?;
            removed.push(manifest_tmp);
        }
        // Root-level `.put-*.icqz.tmp` files from crashed `put_model`
        // calls. `container::save` there runs *before* the lock is
        // taken, so a fresh temp may belong to an in-flight put — only
        // collect ones old enough that their writer is surely gone.
        const STALE_TMP_SECS: u64 = 3600;
        for entry in std::fs::read_dir(&self.root)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if !(path.is_file() && name.starts_with(".put-") && name.ends_with(".tmp")) {
                continue;
            }
            let old_enough = entry_age_secs(&path).map(|a| a > STALE_TMP_SECS);
            if old_enough.unwrap_or(false) {
                std::fs::remove_file(&path)
                    .with_context(|| format!("remove {}", path.display()))?;
                removed.push(path);
            }
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::quant::QuantizerKind;
    use crate::store;
    use crate::synthzoo;

    fn fresh_root(name: &str) -> PathBuf {
        let root = std::env::temp_dir().join("icq_registry_test").join(name);
        let _ = std::fs::remove_dir_all(&root);
        root
    }

    fn demo_container(path: &Path, blocks: usize) {
        let f = synthzoo::family("llama3.2-1b").unwrap();
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let m = store::synth_model(&f, &cfg, Some(blocks)).unwrap();
        container::save(&m, path).unwrap();
    }

    #[test]
    fn put_resolve_list_roundtrip() {
        let root = fresh_root("roundtrip");
        let reg = Registry::open(&root).unwrap();
        let src = root.join("src.icqz");
        demo_container(&src, 1);
        let rec = reg.put_file("demo", &src).unwrap();
        assert_eq!(rec.name, "demo");
        assert_eq!(rec.bytes, std::fs::metadata(&src).unwrap().len());
        assert!(rec.storage_bits_per_weight > 2.0);

        let (r2, path) = reg.resolve("demo").unwrap();
        assert_eq!(r2.hash, rec.hash);
        assert!(path.exists());
        // Resolution by hash prefix.
        let (r3, _) = reg.resolve(&format!("demo@{}", &rec.hash[..8])).unwrap();
        assert_eq!(r3.hash, rec.hash);
        assert_eq!(reg.list().unwrap().len(), 1);
        // Idempotent re-put.
        let rec2 = reg.put_file("demo", &src).unwrap();
        assert_eq!(rec2.hash, rec.hash);
        assert_eq!(reg.list().unwrap().len(), 1);
        // Spec formatting.
        assert!(rec.spec().starts_with("demo@"));
    }

    #[test]
    fn resolve_picks_newest_and_rejects_unknown() {
        let root = fresh_root("newest");
        let reg = Registry::open(&root).unwrap();
        let a = root.join("a.icqz");
        let b = root.join("b.icqz");
        demo_container(&a, 1);
        demo_container(&b, 2);
        let ra = reg.put_file("m", &a).unwrap();
        let rb = reg.put_file("m", &b).unwrap();
        assert_ne!(ra.hash, rb.hash);
        let (newest, _) = reg.resolve("m").unwrap();
        assert_eq!(newest.hash, rb.hash);
        let (old, _) = reg.resolve(&format!("m@{}", &ra.hash[..10])).unwrap();
        assert_eq!(old.hash, ra.hash);
        assert!(reg.resolve("other").is_err());
        assert!(reg.resolve("m@ffffffffffff").is_err());
    }

    #[test]
    fn verify_detects_object_tampering() {
        let root = fresh_root("tamper");
        let reg = Registry::open(&root).unwrap();
        let src = root.join("src.icqz");
        demo_container(&src, 1);
        let rec = reg.put_file("demo", &src).unwrap();
        assert!(reg.verify("demo").unwrap().ok());
        // Flip one byte in the stored object.
        let obj = reg.object_path(&rec.hash);
        let mut bytes = std::fs::read(&obj).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&obj, &bytes).unwrap();
        let report = reg.verify("demo").unwrap();
        assert!(!report.ok(), "tampered object passed verify");
    }

    #[test]
    fn gc_removes_only_unreferenced_objects() {
        let root = fresh_root("gc");
        let reg = Registry::open(&root).unwrap();
        let src = root.join("src.icqz");
        demo_container(&src, 1);
        let rec = reg.put_file("demo", &src).unwrap();
        // Drop an orphan object alongside the live one.
        let orphan = root.join("objects").join(format!("{}.icqz", "0".repeat(32)));
        std::fs::write(&orphan, b"junk").unwrap();
        // A *fresh* put temp at the root must survive gc (it may belong
        // to an in-flight put; only hour-old debris is collected).
        let fresh_tmp = root.join(".put-live-1-1.icqz.tmp");
        std::fs::write(&fresh_tmp, b"in flight").unwrap();
        let removed = reg.gc().unwrap();
        assert_eq!(removed, vec![orphan.clone()]);
        assert!(!orphan.exists());
        assert!(fresh_tmp.exists());
        assert!(reg.object_path(&rec.hash).exists());
    }

    #[test]
    fn gc_sweeps_crashed_put_debris() {
        let root = fresh_root("gc_debris");
        let reg = Registry::open(&root).unwrap();
        let src = root.join("src.icqz");
        demo_container(&src, 1);
        let rec = reg.put_file("demo", &src).unwrap();
        // A crashed object copy: `put_file` writes `<hash>.icqz.tmp`
        // then renames; dying in between strands the temp forever.
        let obj_tmp = root.join("objects").join(format!("{}.icqz.tmp", "a".repeat(32)));
        std::fs::write(&obj_tmp, b"half-copied object").unwrap();
        // A crashed manifest commit: `write_manifest` dying between
        // write and rename strands `manifest.json.tmp` at the root.
        let manifest_tmp = root.join("manifest.json.tmp");
        std::fs::write(&manifest_tmp, b"{\"artifacts\": []}").unwrap();
        let removed = reg.gc().unwrap();
        assert!(removed.contains(&obj_tmp), "gc left {:?} (removed {:?})", obj_tmp, removed);
        assert!(removed.contains(&manifest_tmp), "gc left manifest.json.tmp: {:?}", removed);
        assert_eq!(removed.len(), 2);
        assert!(!obj_tmp.exists());
        assert!(!manifest_tmp.exists());
        // The live object and its manifest record are untouched.
        assert!(reg.object_path(&rec.hash).exists());
        assert_eq!(reg.list().unwrap().len(), 1);
        assert!(reg.resolve("demo").is_ok());
    }

    #[test]
    fn rejects_bad_names_and_non_containers() {
        let root = fresh_root("badput");
        let reg = Registry::open(&root).unwrap();
        let junk = root.join("junk.bin");
        std::fs::write(&junk, b"not a container").unwrap();
        assert!(reg.put_file("x", &junk).is_err());
        let src = root.join("src.icqz");
        demo_container(&src, 1);
        assert!(reg.put_file("bad@name", &src).is_err());
        assert!(reg.put_file("", &src).is_err());
    }

    #[test]
    fn concurrent_puts_lose_no_records() {
        let root = fresh_root("concurrent");
        let reg = std::sync::Arc::new(Registry::open(&root).unwrap());
        let src = root.join("src.icqz");
        demo_container(&src, 1);
        let mut handles = Vec::new();
        for i in 0..4 {
            let reg = reg.clone();
            let src = src.clone();
            handles.push(std::thread::spawn(move || {
                reg.put_file(&format!("m{}", i), &src).unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // All four manifest records survive the concurrent read-modify-
        // write, and the shared object deduplicated to one file.
        assert_eq!(reg.list().unwrap().len(), 4);
        for i in 0..4 {
            assert!(reg.resolve(&format!("m{}", i)).is_ok());
        }
        // Lock file is released.
        assert!(!root.join("registry.lock").exists());
    }

    #[test]
    fn content_hash_is_stable_and_sensitive() {
        let h1 = content_hash(b"hello");
        assert_eq!(h1.len(), 32);
        assert_eq!(h1, content_hash(b"hello"));
        assert_ne!(h1, content_hash(b"hellp"));
        assert_ne!(content_hash(b""), content_hash(b"\0"));
    }
}

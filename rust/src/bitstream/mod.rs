//! Bit-level packing primitives.
//!
//! Everything ICQuant stores — n-bit code planes, b-bit gap streams — is a
//! dense LSB-first bit stream. [`BitWriter`]/[`BitReader`] are the scalar
//! codec; [`PackedPlane`] is the bulk fixed-width container used for code
//! planes with a fast unpack path.

pub mod plane;

pub use plane::{pack_aligned_u8, unpack_aligned_u8, PackedPlane};

/// Append-only LSB-first bit writer.
///
/// Bits are packed into bytes starting from bit 0 of byte 0; a value
/// written with `width` w occupies the next w bits.
#[derive(Default, Clone, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the stream.
    len_bits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bits.div_ceil(8)), len_bits: 0 }
    }

    /// Write the low `width` bits of `v` (width 1..=57).
    #[inline]
    pub fn write(&mut self, v: u64, width: u32) {
        debug_assert!(width >= 1 && width <= 57, "width {}", width);
        debug_assert!(width == 64 || v < (1u64 << width), "value {} overflows width {}", v, width);
        let bit_off = self.len_bits & 7;
        let need_bytes = (self.len_bits + width as usize).div_ceil(8);
        self.buf.resize(need_bytes, 0);
        let byte_idx = self.len_bits >> 3;
        // Merge into an 8-byte window (width ≤ 57 ⇒ fits with any offset).
        let mut window = 0u64;
        let avail = self.buf.len() - byte_idx;
        let n = avail.min(8);
        window |= u64_from_le_prefix(&self.buf[byte_idx..byte_idx + n]);
        window |= v << bit_off;
        let out = window.to_le_bytes();
        self.buf[byte_idx..byte_idx + n].copy_from_slice(&out[..n]);
        self.len_bits += width as usize;
    }

    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

#[inline]
fn u64_from_le_prefix(b: &[u8]) -> u64 {
    let mut tmp = [0u8; 8];
    tmp[..b.len()].copy_from_slice(b);
    u64::from_le_bytes(tmp)
}

/// LSB-first bit reader over a byte slice.
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos_bits: usize,
    len_bits: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8], len_bits: usize) -> Self {
        assert!(len_bits <= buf.len() * 8);
        BitReader { buf, pos_bits: 0, len_bits }
    }

    /// Read `width` bits (1..=57). Panics past end in debug; returns
    /// zero-padded bits in release reads past the logical end but within
    /// the buffer — callers must track counts (the codecs do).
    #[inline]
    pub fn read(&mut self, width: u32) -> u64 {
        debug_assert!(self.pos_bits + width as usize <= self.len_bits, "bitreader overrun");
        let byte_idx = self.pos_bits >> 3;
        let bit_off = self.pos_bits & 7;
        let end = (byte_idx + 8).min(self.buf.len());
        let window = u64_from_le_prefix(&self.buf[byte_idx..end]);
        let v = (window >> bit_off) & mask(width);
        self.pos_bits += width as usize;
        v
    }

    #[inline]
    pub fn remaining_bits(&self) -> usize {
        self.len_bits - self.pos_bits
    }

    pub fn pos_bits(&self) -> usize {
        self.pos_bits
    }

    /// Jump to an absolute bit offset.
    pub fn seek(&mut self, bit: usize) {
        assert!(bit <= self.len_bits);
        self.pos_bits = bit;
    }
}

#[inline]
pub fn mask(width: u32) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};

    #[test]
    fn single_values() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0b1, 1);
        w.write(0xFF, 8);
        assert_eq!(w.len_bits(), 12);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes, 12);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(1), 0b1);
        assert_eq!(r.read(8), 0xFF);
        assert_eq!(r.remaining_bits(), 0);
    }

    #[test]
    fn cross_byte_boundaries() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.write(i % 32, 5);
        }
        let n = w.len_bits();
        let bytes = w.into_bytes();
        assert_eq!(n, 500);
        let mut r = BitReader::new(&bytes, n);
        for i in 0..100u64 {
            assert_eq!(r.read(5), i % 32, "i={}", i);
        }
    }

    #[test]
    fn wide_values_near_57() {
        let mut w = BitWriter::new();
        let vals = [(1u64 << 57) - 1, 0, 0x1234_5678_9ABC_DE, 42];
        for &v in &vals {
            w.write(v, 57);
        }
        let bytes = w.as_bytes().to_vec();
        let mut r = BitReader::new(&bytes, w.len_bits());
        for &v in &vals {
            assert_eq!(r.read(57), v);
        }
    }

    #[test]
    fn seek_random_access() {
        let mut w = BitWriter::new();
        for i in 0..64u64 {
            w.write(i, 6);
        }
        let bytes = w.as_bytes().to_vec();
        let mut r = BitReader::new(&bytes, w.len_bits());
        r.seek(6 * 10);
        assert_eq!(r.read(6), 10);
        r.seek(0);
        assert_eq!(r.read(6), 0);
    }

    #[test]
    fn prop_roundtrip_mixed_widths() {
        check(
            "bitstream-roundtrip",
            Config::with_cases(128),
            |rng, size| {
                let n = 1 + (size * 400.0) as usize;
                (0..n)
                    .map(|_| {
                        let width = rng.range_inclusive(1, 57) as u32;
                        let v = rng.next_u64() & mask(width);
                        (v, width)
                    })
                    .collect::<Vec<(u64, u32)>>()
            },
            |items| {
                let mut w = BitWriter::new();
                for &(v, width) in items {
                    w.write(v, width);
                }
                let total: usize = items.iter().map(|&(_, w)| w as usize).sum();
                crate::prop_assert!(w.len_bits() == total, "len mismatch");
                let bytes = w.as_bytes();
                let mut r = BitReader::new(bytes, total);
                for &(v, width) in items {
                    let got = r.read(width);
                    crate::prop_assert!(got == v, "got {} want {} width {}", got, v, width);
                }
                Ok(())
            },
        );
    }
}

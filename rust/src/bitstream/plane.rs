//! Fixed-width packed code plane.
//!
//! The quantized weight matrix is stored as one code per weight at a fixed
//! bit width (the paper's `n`). [`PackedPlane`] packs those codes densely
//! (LSB-first, row-major) and provides bulk unpack into `u8`/`u16` — the
//! load-time path that turns the storage plane into the runtime plane the
//! kernels consume (see DESIGN.md §4/§8).
//!
//! Two layouts share the type:
//!
//! * **dense** ([`PackedPlane::pack`]) — one contiguous bit stream, no
//!   padding anywhere; the on-disk storage form, where every padding bit
//!   would show up in the bits/weight accounting.
//! * **row-aligned** ([`PackedPlane::pack_row_aligned`]) — each row starts
//!   on a byte boundary (`row_stride` bytes per row, ≤7 padding bits per
//!   row). This is the serving form: the fused kernels unpack one BLOCK of
//!   codes at a time, and because `BLOCK·width` is a whole number of bytes,
//!   every block within a row also starts byte-aligned — the in-loop
//!   unpackers ([`unpack_aligned_u8`]) never straddle a row or need a bit
//!   offset.

use super::{mask, BitReader, BitWriter};

/// Densely packed `width`-bit codes (row-major over a `rows × cols` grid).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPlane {
    pub rows: usize,
    pub cols: usize,
    pub width: u32,
    /// Bytes per row for the row-aligned layout; 0 = dense bit stream.
    row_stride: usize,
    bytes: Vec<u8>,
}

/// Unpack `out.len()` fixed-width codes from `src`, starting at byte 0
/// (the start must be byte-aligned — row-aligned planes guarantee this
/// for row starts and for every `BLOCK`-multiple column offset).
///
/// Width 8 is a copy; widths 1..=7 run a fixed-width octet path (8 codes
/// per `width` bytes through one `u64` window) with a shift-register tail
/// for the final `len % 8` codes.
pub fn unpack_aligned_u8(src: &[u8], width: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&width), "aligned unpack supports width 1..=8");
    let len = out.len();
    if width == 8 {
        out.copy_from_slice(&src[..len]);
        return;
    }
    let w = width as usize;
    let m = mask(width) as u8;
    let groups = len / 8;
    for g in 0..groups {
        let off = g * w;
        let mut buf = [0u8; 8];
        buf[..w].copy_from_slice(&src[off..off + w]);
        let window = u64::from_le_bytes(buf);
        let dst = &mut out[g * 8..g * 8 + 8];
        for (j, slot) in dst.iter_mut().enumerate() {
            *slot = ((window >> (j * w)) as u8) & m;
        }
    }
    // Tail: < 8 codes, shift-register over the remaining bytes.
    let mut produced = groups * 8;
    let mut byte_idx = groups * w;
    let mut window = 0u64;
    let mut avail = 0usize;
    while produced < len {
        while avail < w {
            window |= (src[byte_idx] as u64) << avail;
            avail += 8;
            byte_idx += 1;
        }
        out[produced] = (window as u8) & m;
        window >>= w;
        avail -= w;
        produced += 1;
    }
}

/// Pack `codes` (each `< 2^width`) into `dst` starting at byte 0, LSB
/// first. `dst` must hold `⌈codes.len()·width/8⌉` bytes and arrive
/// zeroed beyond that point (row-stride padding bits stay 0).
pub fn pack_aligned_u8(codes: &[u8], width: u32, dst: &mut [u8]) {
    assert!((1..=8).contains(&width), "aligned pack supports width 1..=8");
    if width == 8 {
        dst[..codes.len()].copy_from_slice(codes);
        return;
    }
    let w = width as usize;
    let mut window = 0u64;
    let mut avail = 0usize;
    let mut byte_idx = 0usize;
    for &c in codes {
        debug_assert!((c as u64) <= mask(width), "code {} overflows width {}", c, width);
        window |= (c as u64) << avail;
        avail += w;
        while avail >= 8 {
            dst[byte_idx] = window as u8;
            window >>= 8;
            avail -= 8;
            byte_idx += 1;
        }
    }
    if avail > 0 {
        dst[byte_idx] = window as u8;
    }
}

impl PackedPlane {
    /// Pack `codes` (len == rows*cols, each < 2^width).
    ///
    /// # Examples
    ///
    /// ```
    /// use icquant::bitstream::PackedPlane;
    ///
    /// // A 2×3 grid of 2-bit codes packs into 12 bits (2 bytes).
    /// let codes: Vec<u16> = vec![3, 0, 1, 2, 3, 1];
    /// let plane = PackedPlane::pack(2, 3, 2, &codes);
    /// assert_eq!(plane.storage_bits(), 12);
    /// assert_eq!(plane.storage_bytes(), 2);
    /// assert_eq!(plane.unpack(), codes);
    /// ```
    pub fn pack(rows: usize, cols: usize, width: u32, codes: &[u16]) -> PackedPlane {
        assert_eq!(codes.len(), rows * cols);
        assert!(width >= 1 && width <= 16);
        let mut w = BitWriter::with_capacity_bits(codes.len() * width as usize);
        for &c in codes {
            debug_assert!((c as u64) <= mask(width), "code {} overflows width {}", c, width);
            w.write(c as u64, width);
        }
        PackedPlane { rows, cols, width, row_stride: 0, bytes: w.into_bytes() }
    }

    /// Bytes one row occupies in the row-aligned layout.
    pub fn aligned_row_stride(cols: usize, width: u32) -> usize {
        (cols * width as usize).div_ceil(8)
    }

    /// Pack `codes` row-aligned: every row starts on a byte boundary
    /// (≤7 padding bits per row). Width is limited to 8 — this is the
    /// serving layout, whose codes are staged through `u8` buffers.
    pub fn pack_row_aligned(rows: usize, cols: usize, width: u32, codes: &[u16]) -> PackedPlane {
        assert_eq!(codes.len(), rows * cols);
        assert!((1..=8).contains(&width), "row-aligned planes support width 1..=8");
        let stride = Self::aligned_row_stride(cols, width);
        let mut bytes = vec![0u8; rows * stride];
        let mut row_u8 = vec![0u8; cols];
        for r in 0..rows {
            for (d, &c) in row_u8.iter_mut().zip(&codes[r * cols..(r + 1) * cols]) {
                debug_assert!((c as u64) <= mask(width), "code {} overflows width {}", c, width);
                *d = c as u8;
            }
            pack_aligned_u8(&row_u8, width, &mut bytes[r * stride..(r + 1) * stride]);
        }
        PackedPlane { rows, cols, width, row_stride: stride, bytes }
    }

    /// Rebuild a row-aligned plane from its raw bytes (the fused
    /// storage→runtime decode packs rows directly into this buffer).
    pub fn from_row_aligned_bytes(
        rows: usize,
        cols: usize,
        width: u32,
        bytes: Vec<u8>,
    ) -> PackedPlane {
        assert!((1..=8).contains(&width), "row-aligned planes support width 1..=8");
        let stride = Self::aligned_row_stride(cols, width);
        assert_eq!(bytes.len(), rows * stride, "row-aligned byte length mismatch");
        PackedPlane { rows, cols, width, row_stride: stride, bytes }
    }

    /// Whether rows start on byte boundaries (serving layout).
    pub fn is_row_aligned(&self) -> bool {
        self.row_stride != 0
    }

    /// Bytes per row (row-aligned planes only).
    pub fn row_stride(&self) -> usize {
        debug_assert!(self.is_row_aligned(), "dense planes have no row stride");
        self.row_stride
    }

    /// One row's packed bytes (row-aligned planes only).
    #[inline]
    pub fn row_bytes(&self, row: usize) -> &[u8] {
        debug_assert!(self.is_row_aligned(), "dense planes have no row slices");
        &self.bytes[row * self.row_stride..(row + 1) * self.row_stride]
    }

    /// Total storage in bytes (row-aligned planes include row padding —
    /// the true resident size).
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Storage in bits (exact code bits, without any padding).
    pub fn storage_bits(&self) -> usize {
        self.rows * self.cols * self.width as usize
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild a dense plane from raw parts (deserialization).
    pub fn from_bytes(rows: usize, cols: usize, width: u32, bytes: Vec<u8>) -> PackedPlane {
        assert!(bytes.len() * 8 >= rows * cols * width as usize);
        PackedPlane { rows, cols, width, row_stride: 0, bytes }
    }

    /// Unpack the whole plane into one `u16` code per weight.
    ///
    /// # Examples
    ///
    /// ```
    /// use icquant::bitstream::PackedPlane;
    ///
    /// let plane = PackedPlane::pack(1, 4, 3, &[7, 1, 0, 5]);
    /// assert_eq!(plane.unpack(), vec![7, 1, 0, 5]);
    /// // The byte-level serving path unpacks into a caller buffer:
    /// let mut bytes = [0u8; 4];
    /// plane.unpack_into_u8(&mut bytes);
    /// assert_eq!(bytes, [7, 1, 0, 5]);
    /// ```
    pub fn unpack(&self) -> Vec<u16> {
        let n = self.rows * self.cols;
        let mut out = Vec::with_capacity(n);
        if self.is_row_aligned() {
            let mut row = vec![0u8; self.cols];
            for r in 0..self.rows {
                unpack_aligned_u8(self.row_bytes(r), self.width, &mut row);
                out.extend(row.iter().map(|&c| c as u16));
            }
            return out;
        }
        let mut r = BitReader::new(&self.bytes, self.storage_bits());
        for _ in 0..n {
            out.push(r.read(self.width) as u16);
        }
        out
    }

    /// Fast bulk unpack into a caller-provided `u8` buffer (width ≤ 8).
    ///
    /// This is the serving load path (§Perf): a 64-bit shift register is
    /// refilled in 8-byte gulps, emitting ⌊56/width⌋ codes per refill —
    /// ~3× the per-code two-byte-window walk it replaced (measured in
    /// `benches/dequant.rs`; before/after in EXPERIMENTS.md §Perf).
    pub fn unpack_into_u8(&self, out: &mut [u8]) {
        assert!(self.width <= 8);
        let n = self.rows * self.cols;
        assert_eq!(out.len(), n);
        if self.is_row_aligned() {
            for (r, chunk) in out.chunks_mut(self.cols).enumerate() {
                unpack_aligned_u8(self.row_bytes(r), self.width, chunk);
            }
            return;
        }
        let width = self.width as usize;
        let m = mask(self.width) as u8;
        let bytes = &self.bytes;

        let mut produced = 0usize;
        let mut byte_idx = 0usize;
        // Shift register: `avail` valid bits at the bottom of `window`.
        let mut window = 0u64;
        let mut avail = 0usize;
        while produced < n {
            // Refill: keep ≥ 56 bits when possible (one branch per gulp,
            // not per code).
            if avail <= 56 {
                while avail <= 56 && byte_idx + 8 <= bytes.len() {
                    // Full 8-byte gulp is only safe when we can consume
                    // 8 whole bytes; otherwise fall to the byte loop.
                    if avail == 0 {
                        window = u64::from_le_bytes(
                            bytes[byte_idx..byte_idx + 8].try_into().unwrap(),
                        );
                        avail = 64;
                        byte_idx += 8;
                    } else {
                        window |= (bytes[byte_idx] as u64) << avail;
                        avail += 8;
                        byte_idx += 1;
                    }
                }
                while avail <= 56 && byte_idx < bytes.len() {
                    window |= (bytes[byte_idx] as u64) << avail;
                    avail += 8;
                    byte_idx += 1;
                }
            }
            // Emit as many codes as the window holds (bounded by n).
            let emit = (avail / width).min(n - produced);
            let dst = &mut out[produced..produced + emit];
            for slot in dst.iter_mut() {
                *slot = (window as u8) & m;
                window >>= width;
            }
            avail -= emit * width;
            produced += emit;
        }
    }

    /// Unpack a single row (width ≤ 8).
    pub fn unpack_row_u8(&self, row: usize, out: &mut [u8]) {
        assert!(self.width <= 8 && row < self.rows);
        assert_eq!(out.len(), self.cols);
        if self.is_row_aligned() {
            return unpack_aligned_u8(self.row_bytes(row), self.width, out);
        }
        let width = self.width as usize;
        let m = mask(self.width);
        let mut bitpos = row * self.cols * width;
        for slot in out.iter_mut() {
            let byte_idx = bitpos >> 3;
            let bit_off = bitpos & 7;
            let w0 = self.bytes[byte_idx] as u64;
            let w1 = *self.bytes.get(byte_idx + 1).unwrap_or(&0) as u64;
            *slot = (((w0 | (w1 << 8)) >> bit_off) & m) as u8;
            bitpos += width;
        }
    }

    /// Read one code.
    pub fn get(&self, row: usize, col: usize) -> u16 {
        let bitpos = if self.is_row_aligned() {
            row * self.row_stride * 8 + col * self.width as usize
        } else {
            (row * self.cols + col) * self.width as usize
        };
        let mut r = BitReader::new(&self.bytes, self.bytes.len() * 8);
        r.seek(bitpos);
        r.read(self.width) as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};
    use crate::util::prng::Rng;

    #[test]
    fn pack_unpack_exact() {
        let codes: Vec<u16> = (0..24).map(|i| (i % 8) as u16).collect();
        let p = PackedPlane::pack(4, 6, 3, &codes);
        assert_eq!(p.storage_bits(), 72);
        assert_eq!(p.unpack(), codes);
    }

    #[test]
    fn u8_bulk_matches_scalar() {
        let mut rng = Rng::new(5);
        for width in 1..=8u32 {
            let (rows, cols) = (17, 129);
            let codes: Vec<u16> =
                (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
            let p = PackedPlane::pack(rows, cols, width, &codes);
            let mut out = vec![0u8; rows * cols];
            p.unpack_into_u8(&mut out);
            for (a, b) in out.iter().zip(&codes) {
                assert_eq!(*a as u16, *b);
            }
        }
    }

    #[test]
    fn row_unpack_matches() {
        let mut rng = Rng::new(9);
        let (rows, cols, width) = (8, 100, 5);
        let codes: Vec<u16> =
            (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
        let p = PackedPlane::pack(rows, cols, width, &codes);
        for r in 0..rows {
            let mut out = vec![0u8; cols];
            p.unpack_row_u8(r, &mut out);
            for c in 0..cols {
                assert_eq!(out[c] as u16, codes[r * cols + c]);
                assert_eq!(p.get(r, c), codes[r * cols + c]);
            }
        }
    }

    #[test]
    fn storage_accounting() {
        let codes = vec![1u16; 1000];
        let p = PackedPlane::pack(10, 100, 2, &codes);
        assert_eq!(p.storage_bits(), 2000);
        assert_eq!(p.storage_bytes(), 250);
    }

    #[test]
    fn row_aligned_roundtrip_all_widths() {
        // Odd col counts force row padding; 3-bit codes cross byte
        // boundaries inside every row.
        let mut rng = Rng::new(17);
        for width in 1..=8u32 {
            for cols in [1usize, 7, 63, 64, 65, 129] {
                let rows = 5;
                let codes: Vec<u16> =
                    (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
                let p = PackedPlane::pack_row_aligned(rows, cols, width, &codes);
                assert!(p.is_row_aligned());
                assert_eq!(p.row_stride(), (cols * width as usize).div_ceil(8));
                assert_eq!(p.storage_bytes(), rows * p.row_stride());
                assert_eq!(p.unpack(), codes, "w={} cols={}", width, cols);
                let mut out = vec![0u8; rows * cols];
                p.unpack_into_u8(&mut out);
                for (a, b) in out.iter().zip(&codes) {
                    assert_eq!(*a as u16, *b);
                }
                let mut row = vec![0u8; cols];
                for r in 0..rows {
                    p.unpack_row_u8(r, &mut row);
                    for c in 0..cols {
                        assert_eq!(row[c] as u16, codes[r * cols + c]);
                        assert_eq!(p.get(r, c), codes[r * cols + c]);
                    }
                }
                // Raw-bytes reconstruction matches.
                let p2 = PackedPlane::from_row_aligned_bytes(
                    rows,
                    cols,
                    width,
                    p.bytes().to_vec(),
                );
                assert_eq!(p2, p);
            }
        }
    }

    #[test]
    fn aligned_pack_unpack_free_fns_match() {
        // The octet fast path and the shift-register tail must agree for
        // every width and every tail length 0..=7.
        let mut rng = Rng::new(23);
        for width in 1..=8u32 {
            for len in [0usize, 1, 5, 8, 9, 16, 23, 512, 513] {
                let codes: Vec<u8> =
                    (0..len).map(|_| (rng.next_u64() & mask(width)) as u8).collect();
                let mut dst = vec![0u8; (len * width as usize).div_ceil(8)];
                pack_aligned_u8(&codes, width, &mut dst);
                let mut back = vec![0u8; len];
                unpack_aligned_u8(&dst, width, &mut back);
                assert_eq!(back, codes, "w={} len={}", width, len);
            }
        }
    }

    #[test]
    fn prop_roundtrip_any_shape_width() {
        check(
            "plane-roundtrip",
            Config::with_cases(96),
            |rng, size| {
                let rows = 1 + (size * 20.0) as usize;
                let cols = 1 + (rng.below(1 + (size * 300.0) as u64)) as usize;
                let width = rng.range_inclusive(1, 16) as u32;
                let codes: Vec<u16> =
                    (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
                (rows, cols, width, codes)
            },
            |(rows, cols, width, codes)| {
                let p = PackedPlane::pack(*rows, *cols, *width, codes);
                crate::prop_assert!(p.unpack() == *codes, "unpack mismatch");
                let p2 = PackedPlane::from_bytes(*rows, *cols, *width, p.bytes().to_vec());
                crate::prop_assert!(p2.unpack() == *codes, "from_bytes mismatch");
                Ok(())
            },
        );
    }
}

//! Fixed-width packed code plane.
//!
//! The quantized weight matrix is stored as one code per weight at a fixed
//! bit width (the paper's `n`). [`PackedPlane`] packs those codes densely
//! (LSB-first, row-major) and provides bulk unpack into `u8`/`u16` — the
//! load-time hot path that turns the storage plane into the byte-aligned
//! runtime plane the kernels consume (see DESIGN.md §4/§8).

use super::{mask, BitReader, BitWriter};

/// Densely packed `width`-bit codes (row-major over a `rows × cols` grid).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPlane {
    pub rows: usize,
    pub cols: usize,
    pub width: u32,
    bytes: Vec<u8>,
}

impl PackedPlane {
    /// Pack `codes` (len == rows*cols, each < 2^width).
    ///
    /// # Examples
    ///
    /// ```
    /// use icquant::bitstream::PackedPlane;
    ///
    /// // A 2×3 grid of 2-bit codes packs into 12 bits (2 bytes).
    /// let codes: Vec<u16> = vec![3, 0, 1, 2, 3, 1];
    /// let plane = PackedPlane::pack(2, 3, 2, &codes);
    /// assert_eq!(plane.storage_bits(), 12);
    /// assert_eq!(plane.storage_bytes(), 2);
    /// assert_eq!(plane.unpack(), codes);
    /// ```
    pub fn pack(rows: usize, cols: usize, width: u32, codes: &[u16]) -> PackedPlane {
        assert_eq!(codes.len(), rows * cols);
        assert!(width >= 1 && width <= 16);
        let mut w = BitWriter::with_capacity_bits(codes.len() * width as usize);
        for &c in codes {
            debug_assert!((c as u64) <= mask(width), "code {} overflows width {}", c, width);
            w.write(c as u64, width);
        }
        PackedPlane { rows, cols, width, bytes: w.into_bytes() }
    }

    /// Total storage in bytes.
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Storage in bits (exact, without byte padding).
    pub fn storage_bits(&self) -> usize {
        self.rows * self.cols * self.width as usize
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild from raw parts (deserialization).
    pub fn from_bytes(rows: usize, cols: usize, width: u32, bytes: Vec<u8>) -> PackedPlane {
        assert!(bytes.len() * 8 >= rows * cols * width as usize);
        PackedPlane { rows, cols, width, bytes }
    }

    /// Unpack the whole plane into one `u16` code per weight.
    ///
    /// # Examples
    ///
    /// ```
    /// use icquant::bitstream::PackedPlane;
    ///
    /// let plane = PackedPlane::pack(1, 4, 3, &[7, 1, 0, 5]);
    /// assert_eq!(plane.unpack(), vec![7, 1, 0, 5]);
    /// // The byte-level serving path unpacks into a caller buffer:
    /// let mut bytes = [0u8; 4];
    /// plane.unpack_into_u8(&mut bytes);
    /// assert_eq!(bytes, [7, 1, 0, 5]);
    /// ```
    pub fn unpack(&self) -> Vec<u16> {
        let n = self.rows * self.cols;
        let mut out = Vec::with_capacity(n);
        let mut r = BitReader::new(&self.bytes, self.storage_bits());
        for _ in 0..n {
            out.push(r.read(self.width) as u16);
        }
        out
    }

    /// Fast bulk unpack into a caller-provided `u8` buffer (width ≤ 8).
    ///
    /// This is the serving load path (§Perf): a 64-bit shift register is
    /// refilled in 8-byte gulps, emitting ⌊56/width⌋ codes per refill —
    /// ~3× the per-code two-byte-window walk it replaced (measured in
    /// `benches/dequant.rs`; before/after in EXPERIMENTS.md §Perf).
    pub fn unpack_into_u8(&self, out: &mut [u8]) {
        assert!(self.width <= 8);
        let n = self.rows * self.cols;
        assert_eq!(out.len(), n);
        let width = self.width as usize;
        let m = mask(self.width) as u8;
        let bytes = &self.bytes;

        let mut produced = 0usize;
        let mut byte_idx = 0usize;
        // Shift register: `avail` valid bits at the bottom of `window`.
        let mut window = 0u64;
        let mut avail = 0usize;
        while produced < n {
            // Refill: keep ≥ 56 bits when possible (one branch per gulp,
            // not per code).
            if avail <= 56 {
                while avail <= 56 && byte_idx + 8 <= bytes.len() {
                    // Full 8-byte gulp is only safe when we can consume
                    // 8 whole bytes; otherwise fall to the byte loop.
                    if avail == 0 {
                        window = u64::from_le_bytes(
                            bytes[byte_idx..byte_idx + 8].try_into().unwrap(),
                        );
                        avail = 64;
                        byte_idx += 8;
                    } else {
                        window |= (bytes[byte_idx] as u64) << avail;
                        avail += 8;
                        byte_idx += 1;
                    }
                }
                while avail <= 56 && byte_idx < bytes.len() {
                    window |= (bytes[byte_idx] as u64) << avail;
                    avail += 8;
                    byte_idx += 1;
                }
            }
            // Emit as many codes as the window holds (bounded by n).
            let emit = (avail / width).min(n - produced);
            let dst = &mut out[produced..produced + emit];
            for slot in dst.iter_mut() {
                *slot = (window as u8) & m;
                window >>= width;
            }
            avail -= emit * width;
            produced += emit;
        }
    }

    /// Unpack a single row (width ≤ 8).
    pub fn unpack_row_u8(&self, row: usize, out: &mut [u8]) {
        assert!(self.width <= 8 && row < self.rows);
        assert_eq!(out.len(), self.cols);
        let width = self.width as usize;
        let m = mask(self.width);
        let mut bitpos = row * self.cols * width;
        for slot in out.iter_mut() {
            let byte_idx = bitpos >> 3;
            let bit_off = bitpos & 7;
            let w0 = self.bytes[byte_idx] as u64;
            let w1 = *self.bytes.get(byte_idx + 1).unwrap_or(&0) as u64;
            *slot = (((w0 | (w1 << 8)) >> bit_off) & m) as u8;
            bitpos += width;
        }
    }

    /// Read one code.
    pub fn get(&self, row: usize, col: usize) -> u16 {
        let bitpos = (row * self.cols + col) * self.width as usize;
        let mut r = BitReader::new(&self.bytes, self.bytes.len() * 8);
        r.seek(bitpos);
        r.read(self.width) as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};
    use crate::util::prng::Rng;

    #[test]
    fn pack_unpack_exact() {
        let codes: Vec<u16> = (0..24).map(|i| (i % 8) as u16).collect();
        let p = PackedPlane::pack(4, 6, 3, &codes);
        assert_eq!(p.storage_bits(), 72);
        assert_eq!(p.unpack(), codes);
    }

    #[test]
    fn u8_bulk_matches_scalar() {
        let mut rng = Rng::new(5);
        for width in 1..=8u32 {
            let (rows, cols) = (17, 129);
            let codes: Vec<u16> =
                (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
            let p = PackedPlane::pack(rows, cols, width, &codes);
            let mut out = vec![0u8; rows * cols];
            p.unpack_into_u8(&mut out);
            for (a, b) in out.iter().zip(&codes) {
                assert_eq!(*a as u16, *b);
            }
        }
    }

    #[test]
    fn row_unpack_matches() {
        let mut rng = Rng::new(9);
        let (rows, cols, width) = (8, 100, 5);
        let codes: Vec<u16> =
            (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
        let p = PackedPlane::pack(rows, cols, width, &codes);
        for r in 0..rows {
            let mut out = vec![0u8; cols];
            p.unpack_row_u8(r, &mut out);
            for c in 0..cols {
                assert_eq!(out[c] as u16, codes[r * cols + c]);
                assert_eq!(p.get(r, c), codes[r * cols + c]);
            }
        }
    }

    #[test]
    fn storage_accounting() {
        let codes = vec![1u16; 1000];
        let p = PackedPlane::pack(10, 100, 2, &codes);
        assert_eq!(p.storage_bits(), 2000);
        assert_eq!(p.storage_bytes(), 250);
    }

    #[test]
    fn prop_roundtrip_any_shape_width() {
        check(
            "plane-roundtrip",
            Config::with_cases(96),
            |rng, size| {
                let rows = 1 + (size * 20.0) as usize;
                let cols = 1 + (rng.below(1 + (size * 300.0) as u64)) as usize;
                let width = rng.range_inclusive(1, 16) as u32;
                let codes: Vec<u16> =
                    (0..rows * cols).map(|_| (rng.next_u64() & mask(width)) as u16).collect();
                (rows, cols, width, codes)
            },
            |(rows, cols, width, codes)| {
                let p = PackedPlane::pack(*rows, *cols, *width, codes);
                crate::prop_assert!(p.unpack() == *codes, "unpack mismatch");
                let p2 = PackedPlane::from_bytes(*rows, *cols, *width, p.bytes().to_vec());
                crate::prop_assert!(p2.unpack() == *codes, "from_bytes mismatch");
                Ok(())
            },
        );
    }
}

//! Lemma 1: expected index-coding overhead under uniform outlier positions,
//! the optimal-`b` search it enables, and the Monte-Carlo simulation used
//! to validate it (paper Fig 4 / Fig 8 / Appendix D).

use super::coding::encoded_symbol_count;
use crate::util::prng::Rng;

/// Lemma 1 upper bound on the expected overhead `E(B)` in bits/weight:
///
/// `E(B) ≤ γ·b·(1 + 1/(e^{γ(2^b−1)} − 1))`
pub fn lemma1_bound(gamma: f64, b: u32) -> f64 {
    assert!(gamma > 0.0 && gamma < 1.0);
    let m = (1u64 << b) as f64 - 1.0;
    let denom = (gamma * m).exp() - 1.0;
    gamma * b as f64 * (1.0 + 1.0 / denom)
}

/// Choose the gap width `b` minimizing the Lemma 1 bound for a given
/// outlier ratio. This is how ICQuant picks b=6 at γ=5 %.
pub fn optimal_b(gamma: f64) -> u32 {
    (1..=15u32)
        .min_by(|&a, &b| {
            lemma1_bound(gamma, a)
                .partial_cmp(&lemma1_bound(gamma, b))
                .unwrap()
        })
        .unwrap()
}

/// Monte-Carlo estimate of the true `E(B)` with uniformly placed outliers
/// (the "synthetic" curve in Fig 4). Returns bits/weight averaged over
/// `trials` rows of width `d`.
pub fn simulate_overhead(d: usize, gamma: f64, b: u32, trials: usize, seed: u64) -> f64 {
    let p = (gamma * d as f64).floor() as usize;
    assert!(p >= 1, "no outliers at gamma={} d={}", gamma, d);
    let mut rng = Rng::new(seed);
    let mut total_bits = 0usize;
    for _ in 0..trials {
        let positions = rng.sample_indices(d, p);
        total_bits += encoded_symbol_count(&positions, b) * b as usize;
    }
    total_bits as f64 / (trials * d) as f64
}

/// Empirical overhead of coding a *given* set of per-row outlier positions
/// (the "empirical" curve in Fig 4, fed with model weights).
pub fn empirical_overhead(rows: &[Vec<usize>], d: usize, b: u32) -> f64 {
    let total_bits: usize = rows
        .iter()
        .map(|pos| encoded_symbol_count(pos, b) * b as usize)
        .sum();
    total_bits as f64 / (rows.len() * d) as f64
}

/// Storage comparison table (paper §3.2): bits/weight for the three
/// strategies at ratio γ and row width d.
pub struct StorageComparison {
    pub binary_mask: f64,
    pub absolute_indices: f64,
    pub icquant: f64,
    pub icquant_b: u32,
}

pub fn storage_comparison(gamma: f64, d: usize) -> StorageComparison {
    let idx_bits = (usize::BITS - (d - 1).leading_zeros()).max(1) as f64;
    // Absolute indices are byte/half-aligned in practice (paper: 16 bits).
    let idx_bits_practical = if idx_bits <= 16.0 { 16.0 } else { 32.0 };
    let b = optimal_b(gamma);
    StorageComparison {
        binary_mask: 1.0,
        absolute_indices: gamma * idx_bits_practical,
        icquant: lemma1_bound(gamma, b),
        icquant_b: b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_numbers() {
        // Paper: γ=5 %, b=6 ⇒ B ≈ 0.31 bits/weight.
        let bound = lemma1_bound(0.05, 6);
        assert!((bound - 0.31).abs() < 0.02, "bound={}", bound);
        // And the optimal b at 5 % is 6 (Fig 4 minimum).
        assert_eq!(optimal_b(0.05), 6);
    }

    #[test]
    fn bound_convex_in_b_around_optimum() {
        // Fig 4 shows a convex trade-off: large b wastes base bits, small b
        // pays escape-flag accumulation.
        let g = 0.05;
        let b_opt = optimal_b(g);
        let at = |b| lemma1_bound(g, b);
        assert!(at(b_opt) < at(b_opt - 2));
        assert!(at(b_opt) < at(b_opt + 3));
    }

    #[test]
    fn simulation_below_bound_and_close() {
        // Fig 4: bound, synthetic simulation, and empirical curves almost
        // coincide. Simulated E(B) must not exceed the bound, and should be
        // within 10 % of it at the operating point.
        let (d, gamma) = (4096, 0.05);
        for b in 4..=8 {
            let bound = lemma1_bound(gamma, b);
            let sim = simulate_overhead(d, gamma, b, 200, 42);
            assert!(sim <= bound * 1.005, "b={} sim {} > bound {}", b, sim, bound);
            assert!(sim >= bound * 0.80, "b={} sim {} far below bound {}", b, sim, bound);
        }
    }

    #[test]
    fn overhead_beats_alternatives() {
        // §3.2: mask costs 1 bit, absolute indices ≈0.8 bits (γ=5 %,
        // 16-bit ids), ICQuant ≈0.31.
        let c = storage_comparison(0.05, 50_000);
        assert_eq!(c.binary_mask, 1.0);
        assert!((c.absolute_indices - 0.8).abs() < 1e-9);
        assert!(c.icquant < 0.35);
        assert_eq!(c.icquant_b, 6);
    }

    #[test]
    fn empirical_matches_simulation_for_uniform() {
        let mut rng = Rng::new(7);
        let (d, gamma, b) = (2048, 0.05, 6);
        let p = (gamma * d as f64) as usize;
        let rows: Vec<Vec<usize>> =
            (0..100).map(|_| rng.sample_indices(d, p)).collect();
        let emp = empirical_overhead(&rows, d, b);
        let sim = simulate_overhead(d, gamma, b, 200, 99);
        assert!((emp - sim).abs() / sim < 0.05, "emp {} sim {}", emp, sim);
    }

    #[test]
    fn prop_lemma1_holds_in_expectation() {
        // Property: across random (d, γ, b), average measured overhead over
        // many uniform rows stays ≤ the Lemma 1 bound (with MC slack).
        use crate::util::miniprop::{check, Config};
        check(
            "lemma1-bound-holds",
            Config::with_cases(40),
            |rng, size| {
                let d = 256 + (size * 4096.0) as usize;
                let gamma = 0.01 + rng.f64() * 0.12;
                let b = rng.range_inclusive(3, 10) as u32;
                let seed = rng.next_u64();
                (d, gamma, b, seed)
            },
            |&(d, gamma, b, seed)| {
                if (gamma * d as f64) < 1.0 {
                    return Ok(()); // no outliers — vacuous
                }
                let sim = simulate_overhead(d, gamma, b, 64, seed);
                let bound = lemma1_bound(gamma, b);
                crate::prop_assert!(
                    sim <= bound * 1.02 + 1e-6,
                    "sim {} > bound {} (d={} γ={} b={})",
                    sim, bound, d, gamma, b
                );
                Ok(())
            },
        );
    }
}

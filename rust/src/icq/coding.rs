//! Gap encoder/decoder for outlier positions (paper §3.2, Fig 3(b)).
//!
//! # Scheme
//!
//! Positions are 0-based column indices within one row. Define gaps
//! `x_0 = i_0 + 1` and `x_k = i_k − i_{k−1}` (all ≥ 1). Each gap is emitted
//! as a sequence of b-bit symbols: symbol values `0..=2^b−2` encode the gap
//! values `1..=2^b−1` directly; the all-ones symbol `2^b−1` (the paper's
//! "value 2^b" flag) means *empty interval* — advance `2^b − 1` positions
//! and keep accumulating. A gap `x` therefore costs
//! `⌊(x−1)/(2^b−1)⌋ + 1` symbols.
//!
//! The paper's Appendix A stores `x mod (2^b−1)` after the flags, which is
//! ambiguous when `x ≡ 0 (mod 2^b−1)`; we resolve it by accumulating while
//! the *remaining* gap exceeds `2^b − 1`, which is bijective and matches
//! the paper's storage count for all other `x`. Documented here because it
//! is load-bearing for decode correctness.

use crate::bitstream::{BitReader, BitWriter};

/// Encode 0-based, strictly-increasing outlier positions into b-bit
/// symbols. Returns the raw symbol sequence (unpacked).
pub fn encode_gaps(positions: &[usize], b: u32) -> Vec<u16> {
    assert!((1..=15).contains(&b), "gap width b must be in 1..=15, got {}", b);
    let flag = (1u32 << b) - 1; // all-ones symbol = empty-interval escape
    let span = flag as usize; // 2^b − 1 positions per escape
    let mut symbols = Vec::with_capacity(positions.len() + positions.len() / 4);
    let mut prev: isize = -1;
    for (k, &pos) in positions.iter().enumerate() {
        let gap = pos as isize - prev;
        assert!(gap >= 1, "positions must be strictly increasing (at entry {})", k);
        let mut gap = gap as usize;
        while gap > span {
            symbols.push(flag as u16);
            gap -= span;
        }
        // gap ∈ 1..=span → symbol gap−1 ∈ 0..=flag−1
        symbols.push((gap - 1) as u16);
        prev = pos as isize;
    }
    symbols
}

/// Decode b-bit symbols back to 0-based positions.
pub fn decode_gaps(symbols: &[u16], b: u32) -> Vec<usize> {
    let flag = (1u16 << b) - 1;
    let span = flag as usize;
    let mut positions = Vec::new();
    let mut cursor: usize = 0; // number of positions consumed so far
    for &s in symbols {
        if s == flag {
            cursor += span;
        } else {
            cursor += s as usize + 1;
            positions.push(cursor - 1);
        }
    }
    positions
}

/// Number of symbols `encode_gaps` will emit (without allocating).
pub fn encoded_symbol_count(positions: &[usize], b: u32) -> usize {
    let span = (1usize << b) - 1;
    let mut count = 0;
    let mut prev: isize = -1;
    for &pos in positions {
        let gap = (pos as isize - prev) as usize;
        count += (gap - 1) / span + 1;
        prev = pos as isize;
    }
    count
}

/// A packed per-row index code: the bit stream plus enough metadata to
/// decode without external context.
#[derive(Clone, Debug, PartialEq)]
pub struct RowIndexCode {
    pub b: u32,
    pub n_symbols: u32,
    pub n_outliers: u32,
    bytes: Vec<u8>,
}

impl RowIndexCode {
    /// Encode and pack positions for one row.
    pub fn encode(positions: &[usize], b: u32) -> RowIndexCode {
        let symbols = encode_gaps(positions, b);
        let mut w = BitWriter::with_capacity_bits(symbols.len() * b as usize);
        for &s in &symbols {
            w.write(s as u64, b);
        }
        RowIndexCode {
            b,
            n_symbols: symbols.len() as u32,
            n_outliers: positions.len() as u32,
            bytes: w.into_bytes(),
        }
    }

    /// Decode back to positions.
    pub fn decode(&self) -> Vec<usize> {
        // For encode-produced codes `positions.len() == n_outliers`; codes
        // rebuilt via `from_parts` from untrusted bytes may disagree, so
        // deserializers validate the count instead of asserting here
        // (see `icquant::packed::read_from`).
        self.positions().collect()
    }

    /// Stream the decoded positions without allocating — the load-time
    /// hot path ([`crate::icquant::IcqMatrix::to_runtime`] walks every
    /// row's gap stream once per model load).
    pub fn positions(&self) -> Positions<'_> {
        Positions {
            reader: BitReader::new(&self.bytes, self.n_symbols as usize * self.b as usize),
            b: self.b,
            remaining: self.n_symbols as usize,
            cursor: 0,
        }
    }

    /// Decode directly into a boolean outlier mask of length `cols`
    /// (no intermediate Vec).
    pub fn decode_into_mask(&self, mask: &mut [bool]) {
        for p in self.positions() {
            mask[p] = true;
        }
    }

    /// Exact storage cost in bits (stream only; see
    /// [`crate::icquant`] for full artifact accounting).
    pub fn storage_bits(&self) -> usize {
        self.n_symbols as usize * self.b as usize
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn from_parts(b: u32, n_symbols: u32, n_outliers: u32, bytes: Vec<u8>) -> RowIndexCode {
        RowIndexCode { b, n_symbols, n_outliers, bytes }
    }
}

/// Streaming gap-symbol decoder over one row's index code — yields the
/// 0-based outlier positions in ascending order, zero heap allocation.
pub struct Positions<'a> {
    reader: BitReader<'a>,
    b: u32,
    remaining: usize,
    cursor: usize,
}

impl Iterator for Positions<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        let flag = (1u64 << self.b) - 1;
        let span = flag as usize;
        while self.remaining > 0 {
            self.remaining -= 1;
            let s = self.reader.read(self.b);
            if s == flag {
                self.cursor += span;
            } else {
                self.cursor += s as usize + 1;
                return Some(self.cursor - 1);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};

    #[test]
    fn paper_example_small_gaps() {
        // γ=5 %, all gaps ≤ 2^b−1 ⇒ one symbol per outlier.
        let positions = [4usize, 10, 17, 30, 31];
        let b = 5;
        let symbols = encode_gaps(&positions, b);
        assert_eq!(symbols.len(), positions.len());
        assert_eq!(decode_gaps(&symbols, b), positions);
        // Gap values round-trip: first gap is i0+1 = 5 → symbol 4.
        assert_eq!(symbols[0], 4);
    }

    #[test]
    fn escape_flag_for_large_gap() {
        // Gap of 100 with b=5 (span 31): 100 = 31+31+31+7 ⇒ 3 flags + one.
        let positions = [99usize];
        let symbols = encode_gaps(&positions, 5);
        assert_eq!(symbols, vec![31, 31, 31, 6]); // 31 is the flag (2^5−1)
        assert_eq!(decode_gaps(&symbols, 5), positions);
    }

    #[test]
    fn gap_exact_multiple_of_span() {
        // The ambiguous case the paper's appendix glosses: x = k·(2^b−1).
        // x = 62 = 2·31 with b=5 ⇒ one flag then symbol 30 (gap 31).
        let positions = [61usize];
        let symbols = encode_gaps(&positions, 5);
        assert_eq!(symbols, vec![31, 30]);
        assert_eq!(decode_gaps(&symbols, 5), positions);
    }

    #[test]
    fn adjacent_outliers_gap_one() {
        let positions = [0usize, 1, 2, 3];
        let symbols = encode_gaps(&positions, 3);
        assert_eq!(symbols, vec![0, 0, 0, 0]);
        assert_eq!(decode_gaps(&symbols, 3), positions);
    }

    #[test]
    fn empty_positions() {
        assert!(encode_gaps(&[], 6).is_empty());
        assert!(decode_gaps(&[], 6).is_empty());
        let code = RowIndexCode::encode(&[], 6);
        assert_eq!(code.storage_bits(), 0);
        assert!(code.decode().is_empty());
    }

    #[test]
    fn symbol_count_formula() {
        let positions = [99usize, 161, 162];
        for b in 2..=10 {
            assert_eq!(
                encoded_symbol_count(&positions, b),
                encode_gaps(&positions, b).len(),
                "b={}",
                b
            );
        }
    }

    #[test]
    fn packed_roundtrip_and_mask() {
        let positions = [3usize, 64, 65, 500, 1023];
        let code = RowIndexCode::encode(&positions, 6);
        assert_eq!(code.decode(), positions);
        // The streaming iterator yields the same sequence without a Vec.
        assert!(code.positions().eq(positions.iter().copied()));
        assert_eq!(code.positions().count(), positions.len());
        let mut mask = vec![false; 1024];
        code.decode_into_mask(&mut mask);
        for (i, &m) in mask.iter().enumerate() {
            assert_eq!(m, positions.contains(&i), "i={}", i);
        }
        // Serialization roundtrip.
        let code2 = RowIndexCode::from_parts(
            code.b,
            code.n_symbols,
            code.n_outliers,
            code.bytes().to_vec(),
        );
        assert_eq!(code2.decode(), positions);
    }

    #[test]
    fn prop_roundtrip_uniform_positions() {
        check(
            "icq-gap-roundtrip",
            Config::with_cases(200),
            |rng, size| {
                let d = 16 + (size * 8000.0) as usize;
                let gamma = 0.002 + rng.f64() * 0.15;
                let p = ((gamma * d as f64) as usize).min(d);
                let b = rng.range_inclusive(1, 12) as u32;
                let positions = rng.sample_indices(d, p);
                (positions, b)
            },
            |(positions, b)| {
                let code = RowIndexCode::encode(positions, *b);
                let back = code.decode();
                crate::prop_assert!(back == *positions, "roundtrip mismatch b={}", b);
                crate::prop_assert!(
                    code.storage_bits() == encoded_symbol_count(positions, *b) * *b as usize,
                    "storage accounting mismatch"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn prop_roundtrip_clustered_positions() {
        // Worst-case non-uniform (o_proj-like) clustering must still be
        // decoded exactly — the scheme's correctness is distribution-free.
        check(
            "icq-gap-roundtrip-clustered",
            Config::with_cases(100),
            |rng, size| {
                let d = 64 + (size * 4000.0) as usize;
                let b = rng.range_inclusive(2, 8) as u32;
                // Cluster positions at the end of the row.
                let k = 1 + (size * 40.0) as usize;
                let start = d - k.min(d);
                let positions: Vec<usize> = (start..d).collect();
                (positions, b, d)
            },
            |(positions, b, _d)| {
                let code = RowIndexCode::encode(positions, *b);
                crate::prop_assert!(code.decode() == *positions, "clustered roundtrip");
                Ok(())
            },
        );
    }
}

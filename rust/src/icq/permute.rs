//! Random input-channel permutation (paper §2 Observation + Appendix
//! C.2): when outlier positions are *not* naturally uniform (o_proj
//! layers), a one-time random permutation of the columns enforces
//! uniformity without changing the model function — `W P Pᵀ X = W X`,
//! and `P` folds into the adjacent layers so only the seed is stored.
//!
//! This makes ICQuant's Lemma-1 overhead guarantee *unconditional*: apply
//! [`ColumnPermutation`] before quantization whenever the chi-square test
//! rejects, and the gap statistics revert to the uniform case.

use crate::util::prng::Rng;
use crate::util::tensor::Matrix;

/// A seeded column permutation and its inverse.
#[derive(Clone, Debug)]
pub struct ColumnPermutation {
    /// `perm[new_col] = old_col`.
    pub perm: Vec<u32>,
    inv: Vec<u32>,
}

impl ColumnPermutation {
    pub fn new(cols: usize, seed: u64) -> ColumnPermutation {
        let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
        let mut perm: Vec<u32> = (0..cols as u32).collect();
        rng.shuffle(&mut perm);
        let mut inv = vec![0u32; cols];
        for (new, &old) in perm.iter().enumerate() {
            inv[old as usize] = new as u32;
        }
        ColumnPermutation { perm, inv }
    }

    pub fn cols(&self) -> usize {
        self.perm.len()
    }

    /// `W ↦ W P` (shuffle columns).
    pub fn apply(&self, w: &Matrix) -> Matrix {
        assert_eq!(w.cols, self.cols());
        let mut out = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let src = w.row(r);
            let dst = out.row_mut(r);
            for (new, &old) in self.perm.iter().enumerate() {
                dst[new] = src[old as usize];
            }
        }
        out
    }

    /// `W' ↦ W' Pᵀ` (undo).
    pub fn invert(&self, w: &Matrix) -> Matrix {
        assert_eq!(w.cols, self.cols());
        let mut out = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let src = w.row(r);
            let dst = out.row_mut(r);
            for (new, &old) in self.inv.iter().enumerate() {
                dst[new] = src[old as usize];
            }
        }
        out
    }

    /// Permute an activation vector the way `Pᵀ X` requires (so that
    /// `(W P)(Pᵀ x) = W x`): the value feeding old column `c` must land
    /// at the new position of `c`.
    pub fn apply_to_input(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols());
        let mut out = vec![0.0f32; x.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            out[new] = x[old as usize];
        }
        out
    }
}

/// Decide-and-permute helper: returns a permutation only when the
/// layer's outlier positions fail the uniformity test (the paper's
/// conditional application — most layers don't need it).
pub fn permutation_if_needed(
    w: &Matrix,
    gamma: f64,
    group_size: usize,
    alpha: f64,
    reject_threshold: f64,
    seed: u64,
) -> Option<ColumnPermutation> {
    let rate = crate::stats::rejection_rate(w, gamma, group_size, alpha);
    if rate > reject_threshold {
        Some(ColumnPermutation::new(w.cols, seed))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rejection_rate;
    use crate::synthzoo::{family, LayerType};
    use crate::util::miniprop::{check, Config};

    #[test]
    fn permutation_roundtrip() {
        let w = crate::synthzoo::demo_matrix(8, 100, 3);
        let p = ColumnPermutation::new(100, 7);
        assert!(p.invert(&p.apply(&w)).mse(&w) < 1e-12);
    }

    #[test]
    fn model_function_preserved() {
        // (W P)(Pᵀ x) must equal W x — the Appendix C.2 identity.
        let w = crate::synthzoo::demo_matrix(16, 64, 5);
        let p = ColumnPermutation::new(64, 11);
        let wp = p.apply(&w);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).cos()).collect();
        let xp = p.apply_to_input(&x);
        for r in 0..16 {
            let orig: f32 = w.row(r).iter().zip(&x).map(|(a, b)| a * b).sum();
            let perm: f32 = wp.row(r).iter().zip(&xp).map(|(a, b)| a * b).sum();
            assert!((orig - perm).abs() < 1e-4, "row {}: {} vs {}", r, orig, perm);
        }
    }

    #[test]
    fn permutation_enforces_uniformity_on_oproj() {
        // The headline: o_proj rejects at 60-95 %; after a random column
        // permutation the rejection rate falls to the 5 % floor.
        let f = family("llama3-8b").unwrap();
        let w = f.gen_stat_layer(LayerType::OProj, 0);
        let before = rejection_rate(&w, 0.0625, 256, 0.05);
        let p = ColumnPermutation::new(w.cols, 13);
        let after = rejection_rate(&p.apply(&w), 0.0625, 256, 0.05);
        assert!(before > 0.5, "before {}", before);
        assert!(after < 0.15, "after {}", after);
    }

    #[test]
    fn permutation_restores_lemma1_overhead() {
        // Clustered outliers inflate the gap-code cost past the bound;
        // permuting restores it to ≈ the Lemma 1 value.
        use crate::icq::bound::{empirical_overhead, lemma1_bound};
        use crate::quant::mixed_precision::top_k_by_magnitude;
        let f = family("llama3-8b").unwrap();
        let w = f.gen_stat_layer(LayerType::OProj, 0);
        let gamma = 0.05;
        let k = (gamma * w.cols as f64) as usize;
        let b = 6;
        let collect = |m: &Matrix| -> Vec<Vec<usize>> {
            (0..m.rows).map(|r| top_k_by_magnitude(m.row(r), k)).collect()
        };
        let before = empirical_overhead(&collect(&w), w.cols, b);
        let p = ColumnPermutation::new(w.cols, 17);
        let after = empirical_overhead(&collect(&p.apply(&w)), w.cols, b);
        let bound = lemma1_bound(gamma, b);
        assert!(after <= bound * 1.01, "after {} vs bound {}", after, bound);
        assert!(after <= before + 1e-9);
    }

    #[test]
    fn conditional_application() {
        let f = family("llama3-8b").unwrap();
        let q = f.gen_stat_layer(LayerType::QProj, 0);
        let o = f.gen_stat_layer(LayerType::OProj, 0);
        assert!(permutation_if_needed(&q, 0.0625, 256, 0.05, 0.3, 1).is_none());
        assert!(permutation_if_needed(&o, 0.0625, 256, 0.05, 0.3, 1).is_some());
    }

    #[test]
    fn prop_permutation_is_bijective() {
        check(
            "column-permutation-bijection",
            Config::with_cases(64),
            |rng, size| {
                let cols = 2 + (size * 400.0) as usize;
                (cols, rng.next_u64())
            },
            |&(cols, seed)| {
                let p = ColumnPermutation::new(cols, seed);
                let mut seen = vec![false; cols];
                for &c in &p.perm {
                    crate::prop_assert!(!seen[c as usize], "duplicate {}", c);
                    seen[c as usize] = true;
                }
                crate::prop_assert!(seen.iter().all(|&x| x), "not surjective");
                Ok(())
            },
        );
    }
}

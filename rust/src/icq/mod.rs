//! The paper's core contribution: outlier **index coding** (§3.2).
//!
//! Instead of a 1-bit-per-weight outlier mask or ≥16-bit absolute indices,
//! ICQuant stores the *gaps* between consecutive outlier positions in each
//! row using `b` bits per entry, reserving the gap value `2^b` as an escape
//! flag meaning "advance `2^b − 1` positions without emitting an outlier".
//! Under the paper's empirical observation that outlier positions are
//! uniform within a row, Lemma 1 bounds the expected cost at
//! `γ·b·(1 + 1/(e^{γ(2^b−1)} − 1))` bits/weight — ≈0.31 at γ=5 %, b=6.
//!
//! * [`coding`] — the gap encoder/decoder ([`encode_gaps`],
//!   [`decode_gaps`], [`RowIndexCode`]).
//! * [`bound`] — Lemma 1, the optimal-`b` search, and the synthetic
//!   simulation used in Fig 4 / Fig 8.

pub mod bound;
pub mod coding;
pub mod permute;

pub use bound::{lemma1_bound, optimal_b, simulate_overhead};
pub use coding::{decode_gaps, encode_gaps, encoded_symbol_count, Positions, RowIndexCode};
pub use permute::ColumnPermutation;

//! SynthZoo: synthetic model-weight generators reproducing the per-layer
//! statistics the paper measures on Llama2/Llama3/Qwen2.5 (§2, Appendix
//! B/C) — the substitution for the real checkpoints this box cannot hold
//! (see DESIGN.md §2).
//!
//! Three properties are generated faithfully:
//!
//! 1. **Gaussian-like bulk with mild heavy tails** — trained transformer
//!    weights are near-Gaussian (Dettmers 2023); for a Gaussian row of
//!    width 4096, the top-5 % by |w| span ≈50 % of the value range, which
//!    is exactly the paper's Fig 1 observation. A small Student-t
//!    admixture reproduces the spread across layer types.
//! 2. **Uniform outlier positions** in q/k/v/up/gate/down projections
//!    (i.i.d. sampling ⇒ uniform), giving the ~3 % chi-square rejection
//!    rates of Table 1/5 (the test's natural false-positive rate at
//!    α=0.05 plus tail-mixture clustering).
//! 3. **`o_proj` anomaly** — column-structured outlier concentration
//!    (a smooth hot-column profile: some input channels carry
//!    systematically larger weights, as attention-output projections do),
//!    reproducing the 60–95 % rejection rates of Table 1/5.

use crate::util::prng::Rng;
use crate::util::tensor::Matrix;

/// Transformer linear-layer types, as the paper's tables split them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerType {
    QProj,
    KProj,
    VProj,
    OProj,
    UpProj,
    GateProj,
    DownProj,
}

impl LayerType {
    pub const ALL: [LayerType; 7] = [
        LayerType::QProj,
        LayerType::KProj,
        LayerType::VProj,
        LayerType::OProj,
        LayerType::UpProj,
        LayerType::GateProj,
        LayerType::DownProj,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            LayerType::QProj => "q_proj",
            LayerType::KProj => "k_proj",
            LayerType::VProj => "v_proj",
            LayerType::OProj => "o_proj",
            LayerType::UpProj => "up_proj",
            LayerType::GateProj => "gate_proj",
            LayerType::DownProj => "down_proj",
        }
    }
}

/// A synthetic model family: scaled-down dims + tail parameters tuned to
/// reproduce the family's measured outlier statistics.
#[derive(Clone, Debug)]
pub struct FamilySpec {
    pub name: &'static str,
    /// Scaled-down model width (real width / 16).
    pub d_model: usize,
    /// Scaled-down FFN width.
    pub d_ff: usize,
    /// Number of transformer blocks to simulate (scaled down).
    pub n_blocks: usize,
    /// Fraction of weights drawn from the heavy-tail component.
    pub tail_frac: f64,
    /// Scale of the heavy-tail component relative to the bulk.
    pub tail_scale: f64,
    /// Fraction of o_proj output channels (rows) that carry the
    /// hot-column outlier structure. Structured rows reject the
    /// uniformity test with probability ≈1, so this is ≈ the Table 5
    /// rejection rate (Llama3-8B 95 %, Llama2-7B 62 %, …). 0 = none.
    pub oproj_hot: f64,
    pub seed: u64,
}

/// The nine model families of Table 5 (dims /16, blocks /4).
pub fn model_families() -> Vec<FamilySpec> {
    vec![
        FamilySpec { name: "llama2-7b", d_model: 256, d_ff: 688, n_blocks: 8, tail_frac: 0.015, tail_scale: 2.4, oproj_hot: 0.62, seed: 0x7B2 },
        FamilySpec { name: "llama2-13b", d_model: 320, d_ff: 864, n_blocks: 10, tail_frac: 0.013, tail_scale: 2.3, oproj_hot: 0.59, seed: 0x13B2 },
        FamilySpec { name: "llama2-70b", d_model: 512, d_ff: 1792, n_blocks: 20, tail_frac: 0.010, tail_scale: 2.2, oproj_hot: 0.95, seed: 0x70B2 },
        FamilySpec { name: "llama3-8b", d_model: 256, d_ff: 896, n_blocks: 8, tail_frac: 0.012, tail_scale: 2.3, oproj_hot: 0.95, seed: 0x8B3 },
        FamilySpec { name: "llama3-70b", d_model: 512, d_ff: 1792, n_blocks: 20, tail_frac: 0.010, tail_scale: 2.2, oproj_hot: 0.71, seed: 0x70B3 },
        FamilySpec { name: "llama3.2-1b", d_model: 128, d_ff: 512, n_blocks: 4, tail_frac: 0.02, tail_scale: 2.5, oproj_hot: 0.82, seed: 0x1B32 },
        FamilySpec { name: "llama3.2-3b", d_model: 192, d_ff: 512, n_blocks: 7, tail_frac: 0.018, tail_scale: 2.45, oproj_hot: 0.85, seed: 0x3B32 },
        FamilySpec { name: "qwen2.5-7b", d_model: 224, d_ff: 1184, n_blocks: 7, tail_frac: 0.014, tail_scale: 2.35, oproj_hot: 0.95, seed: 0x7B05 },
        FamilySpec { name: "qwen2.5-32b", d_model: 320, d_ff: 1728, n_blocks: 16, tail_frac: 0.011, tail_scale: 2.25, oproj_hot: 0.90, seed: 0x32B0 },
    ]
}

pub fn family(name: &str) -> Option<FamilySpec> {
    model_families().into_iter().find(|f| f.name == name)
}

impl FamilySpec {
    /// Shape of a layer type (rows = output channels, cols = input).
    pub fn layer_shape(&self, lt: LayerType) -> (usize, usize) {
        match lt {
            LayerType::QProj | LayerType::KProj | LayerType::VProj | LayerType::OProj => {
                (self.d_model, self.d_model)
            }
            LayerType::UpProj | LayerType::GateProj => (self.d_ff, self.d_model),
            LayerType::DownProj => (self.d_model, self.d_ff),
        }
    }

    /// Generate one layer's weight matrix.
    pub fn gen_layer(&self, lt: LayerType, block: usize) -> Matrix {
        let (rows, cols) = self.layer_shape(lt);
        self.gen_layer_shaped(lt, block, rows, cols)
    }

    /// Generate a *statistics* layer: same distributional process, but at
    /// half the real model's width (8× the serving-sim width) so the
    /// paper's group-of-256 chi-square test has its intended resolution
    /// (expected count 16 per group at γ=6.25 %). Row count is capped —
    /// statistics are per-row i.i.d., so 96 rows estimate rejection rates
    /// to ±few %.
    pub fn gen_stat_layer(&self, lt: LayerType, block: usize) -> Matrix {
        let (_, cols) = self.layer_shape(lt);
        self.gen_layer_shaped(lt, block, 96, cols * 8)
    }

    fn gen_layer_shaped(&self, lt: LayerType, block: usize, rows: usize, cols: usize) -> Matrix {
        let mut rng = Rng::new(
            self.seed ^ (block as u64).wrapping_mul(0x9E37_79B9)
                ^ (lt as u64).wrapping_mul(0x85EB_CA6B),
        );
        // Per-layer global scale like real init: σ ∝ 1/√fan_in.
        let sigma = 1.0 / (cols as f64).sqrt();

        // o_proj hot-column profile: a few smooth bumps over columns make
        // outliers cluster in specific input channels (breaking per-row
        // positional uniformity). Only a fraction `oproj_hot` of output
        // channels couple to the hot columns — real o_proj layers show
        // exactly this row-level heterogeneity (Table 5 rejection rates
        // sit between 59 % and 95 %, not at 100 %). First blocks carry
        // the strongest structure, mirroring Appendix G.2.
        let col_profile: Option<Vec<f64>> = if lt == LayerType::OProj && self.oproj_hot > 0.0 {
            let depth_factor = 1.0 + 1.0 / (1.0 + block as f64 * 0.5);
            let n_bumps = 3 + (rng.below(3) as usize);
            let mut prof = vec![0.0f64; cols];
            for _ in 0..n_bumps {
                let c0 = rng.below(cols as u64) as f64;
                let width = 4.0 + rng.f64() * (cols as f64 * 0.02);
                let amp = depth_factor * (1.0 + rng.f64());
                for (c, p) in prof.iter_mut().enumerate() {
                    let z = (c as f64 - c0) / width;
                    *p += amp * (-0.5 * z * z).exp();
                }
            }
            Some(prof)
        } else {
            None
        };

        let mut data = Vec::with_capacity(rows * cols);
        for _r in 0..rows {
            // Row-level coupling to the hot columns.
            let coupling = match &col_profile {
                Some(_) if rng.bool(self.oproj_hot) => 0.7 + rng.f64(),
                _ => 0.0,
            };
            for c in 0..cols {
                let x = if rng.bool(self.tail_frac) {
                    rng.student_t(4.0) * self.tail_scale
                } else {
                    rng.normal()
                };
                let cs = match &col_profile {
                    Some(prof) => 1.0 + coupling * prof[c],
                    None => 1.0,
                };
                data.push((x * sigma * cs) as f32);
            }
        }
        Matrix::from_vec(rows, cols, data)
    }

    /// Synthetic per-weight sensitivity matching Fig 9: Fisher scores are
    /// largest for small-magnitude weights and fall off in the tails
    /// (log-normal noise on a center-peaked profile).
    pub fn gen_sensitivity(&self, w: &Matrix, seed_extra: u64) -> Matrix {
        let mut rng = Rng::new(self.seed ^ 0x5E5E ^ seed_extra);
        // Scale of the center peak relative to the weight std.
        let std = (w.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
            / w.numel() as f64)
            .sqrt();
        let data = w
            .data
            .iter()
            .map(|&x| {
                let z = x as f64 / (std + 1e-12);
                let profile = (-0.5 * z * z).exp() + 0.02;
                let noise = (rng.normal() * 0.8).exp();
                (profile * noise) as f32
            })
            .collect();
        Matrix::from_vec(w.rows, w.cols, data)
    }

    /// All (layer-type, block) pairs of the simulated model.
    pub fn all_layers(&self) -> Vec<(LayerType, usize)> {
        let mut v = Vec::new();
        for block in 0..self.n_blocks {
            for lt in LayerType::ALL {
                v.push((lt, block));
            }
        }
        v
    }

    /// Total simulated parameter count.
    pub fn param_count(&self) -> usize {
        self.all_layers()
            .iter()
            .map(|&(lt, _)| {
                let (r, c) = self.layer_shape(lt);
                r * c
            })
            .sum()
    }
}

/// A small heavy-tailed demo matrix for tests/examples/quickstart — one
/// llama2-7b-sim-style layer row structure at arbitrary shape.
pub fn demo_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let sigma = 1.0 / (cols as f64).sqrt();
    let data = (0..rows * cols)
        .map(|_| {
            let x = if rng.bool(0.015) { rng.student_t(4.0) * 2.4 } else { rng.normal() };
            (x * sigma) as f32
        })
        .collect();
    Matrix::from_vec(rows, cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::mixed_precision::top_k_by_magnitude;

    #[test]
    fn family_registry_complete() {
        let fams = model_families();
        assert_eq!(fams.len(), 9);
        assert!(family("llama2-7b").is_some());
        assert!(family("nonexistent").is_none());
        for f in &fams {
            assert!(f.param_count() > 100_000, "{} too small", f.name);
        }
    }

    #[test]
    fn shapes_match_architecture() {
        let f = family("llama2-7b").unwrap();
        assert_eq!(f.layer_shape(LayerType::QProj), (256, 256));
        assert_eq!(f.layer_shape(LayerType::UpProj), (688, 256));
        assert_eq!(f.layer_shape(LayerType::DownProj), (256, 688));
    }

    #[test]
    fn generation_is_deterministic() {
        let f = family("llama3-8b").unwrap();
        let a = f.gen_layer(LayerType::QProj, 0);
        let b = f.gen_layer(LayerType::QProj, 0);
        assert_eq!(a, b);
        let c = f.gen_layer(LayerType::QProj, 1);
        assert_ne!(a, c);
    }

    #[test]
    fn five_pct_outliers_take_about_half_range() {
        // The paper's Fig 1 headline: top-5 % |w| span ≈50 % of the range.
        let f = family("llama2-7b").unwrap();
        for lt in [LayerType::QProj, LayerType::UpProj, LayerType::DownProj] {
            let w = f.gen_layer(lt, 2);
            let mut fracs = Vec::new();
            for r in 0..w.rows.min(64) {
                let row = w.row(r);
                let k = (row.len() as f64 * 0.05) as usize;
                let out = top_k_by_magnitude(row, k);
                let mut mask = vec![false; row.len()];
                for &c in &out {
                    mask[c] = true;
                }
                let (mut ilo, mut ihi) = (f32::INFINITY, f32::NEG_INFINITY);
                let (mut flo, mut fhi) = (f32::INFINITY, f32::NEG_INFINITY);
                for (c, &v) in row.iter().enumerate() {
                    flo = flo.min(v);
                    fhi = fhi.max(v);
                    if !mask[c] {
                        ilo = ilo.min(v);
                        ihi = ihi.max(v);
                    }
                }
                fracs.push(1.0 - ((ihi - ilo) / (fhi - flo)) as f64);
            }
            let mean = fracs.iter().sum::<f64>() / fracs.len() as f64;
            assert!(
                (0.35..0.70).contains(&mean),
                "{:?}: outliers take {:.2} of range",
                lt,
                mean
            );
        }
    }

    #[test]
    fn oproj_columns_are_structured() {
        // Column energy variance must be far higher in o_proj than q_proj.
        let f = family("llama3-8b").unwrap();
        let col_var_ratio = |w: &Matrix| {
            let mut energy = vec![0.0f64; w.cols];
            for r in 0..w.rows {
                for (c, &v) in w.row(r).iter().enumerate() {
                    energy[c] += (v as f64) * (v as f64);
                }
            }
            let mean = energy.iter().sum::<f64>() / w.cols as f64;
            let var = energy.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>()
                / w.cols as f64;
            var / (mean * mean)
        };
        let o = col_var_ratio(&f.gen_layer(LayerType::OProj, 0));
        let q = col_var_ratio(&f.gen_layer(LayerType::QProj, 0));
        assert!(o > q * 5.0, "o_proj col var {} vs q_proj {}", o, q);
    }

    #[test]
    fn sensitivity_center_peaked() {
        // Fig 9: tails have lower sensitivity than the center.
        let f = family("llama2-7b").unwrap();
        let w = f.gen_layer(LayerType::QProj, 0);
        let s = f.gen_sensitivity(&w, 0);
        let k = (w.cols as f64 * 0.05) as usize;
        let mut tail_sens = 0.0f64;
        let mut center_sens = 0.0f64;
        let mut nt = 0usize;
        let mut nc = 0usize;
        for r in 0..w.rows {
            let out = top_k_by_magnitude(w.row(r), k);
            let mut mask = vec![false; w.cols];
            for &c in &out {
                mask[c] = true;
            }
            for c in 0..w.cols {
                if mask[c] {
                    tail_sens += s.get(r, c) as f64;
                    nt += 1;
                } else {
                    center_sens += s.get(r, c) as f64;
                    nc += 1;
                }
            }
        }
        let tail = tail_sens / nt as f64;
        let center = center_sens / nc as f64;
        assert!(center > tail * 2.0, "center {} tail {}", center, tail);
    }

    #[test]
    fn demo_matrix_has_tails() {
        let w = demo_matrix(16, 1024, 3);
        let (lo, hi) = crate::quant::min_max(&w.data);
        let std = (w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / w.numel() as f64)
            .sqrt();
        // Range should be several σ wide (tails present).
        assert!(((hi - lo) as f64) > 6.0 * std);
    }
}

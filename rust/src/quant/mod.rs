//! Quantizers and outlier-suppression baselines.
//!
//! The paper positions ICQuant as a *framework* usable on top of any
//! quantizer (§3) and compares it against the standard suppression
//! techniques (§4.1). This module provides:
//!
//! * [`Codebook`] — the common representation: `2^n` scalar levels per
//!   quantization unit (a row, a group, or a whole tensor).
//! * [`rtn`] — rounding-to-nearest uniform quantization (min/max affine).
//! * [`kmeans`] — sensitivity-aware weighted K-means (SqueezeLLM's
//!   quantizer; ICQuant^SK uses this on each partition).
//! * [`grouping`] — per-group quantization baseline (GPTQ/AWQ-style).
//! * [`clipping`] — grid-searched clipped RTN (OmniQuant-lite).
//! * [`mixed_precision`] — FP16 outliers + quantized inliers
//!   (SqueezeLLM-lite "dense-and-sparse").
//! * [`incoherence`] — randomized-Hadamard incoherence processing
//!   (QuIP/QuIP#-style rotation).
//! * [`vq`] — d-dimensional vector quantization with k-means codebooks
//!   (AQLM/QuIP#-lite).
//! * [`gptq`] — GPTQ adaptive rounding with Hessian error compensation.

pub mod rtn;
pub mod kmeans;
pub mod grouping;
pub mod clipping;
pub mod mixed_precision;
pub mod incoherence;
pub mod vq;
pub mod gptq;

use crate::util::tensor::Matrix;

/// Which base scalar quantizer a method uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum QuantizerKind {
    /// Rounding-to-nearest uniform (affine min/max).
    #[default]
    Rtn,
    /// Sensitivity-aware weighted K-means (SqueezeLLM §E.1).
    SensitiveKmeans,
}

impl QuantizerKind {
    /// Fit a codebook on `values` with optional per-value sensitivity.
    pub fn fit(&self, values: &[f32], sens: Option<&[f32]>, bits: u32) -> Codebook {
        match self {
            QuantizerKind::Rtn => rtn::fit_rtn(values, bits),
            QuantizerKind::SensitiveKmeans => kmeans::fit_kmeans(values, sens, bits, 25),
        }
    }

    /// Bits needed to store this quantizer's parameters for one unit
    /// (per row here): RTN stores (scale, zero) as 2×f16; K-means stores
    /// the full 2^n level table as f16.
    pub fn param_bits(&self, bits: u32) -> usize {
        match self {
            QuantizerKind::Rtn => 2 * 16,
            QuantizerKind::SensitiveKmeans => (1usize << bits) * 16,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantizerKind::Rtn => "RTN",
            QuantizerKind::SensitiveKmeans => "SK",
        }
    }

    /// Canonical on-disk / CLI identifier. The single source of truth for
    /// the `QuantizerKind` ↔ string mapping used by the `ICQM` header,
    /// the `ICQZ` container TOC, and `icquant --quantizer`; the inverse
    /// is the [`std::str::FromStr`] impl below.
    pub fn to_str(&self) -> &'static str {
        match self {
            QuantizerKind::Rtn => "rtn",
            QuantizerKind::SensitiveKmeans => "sk",
        }
    }
}

impl std::str::FromStr for QuantizerKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<QuantizerKind, Self::Err> {
        match s {
            "rtn" => Ok(QuantizerKind::Rtn),
            "sk" => Ok(QuantizerKind::SensitiveKmeans),
            other => Err(anyhow::anyhow!(
                "unknown quantizer '{}' (expected 'rtn' or 'sk')",
                other
            )),
        }
    }
}

/// A scalar codebook: `levels` sorted ascending, one entry per code.
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub levels: Vec<f32>,
}

impl Codebook {
    pub fn new(mut levels: Vec<f32>) -> Codebook {
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Codebook { levels }
    }

    pub fn bits(&self) -> u32 {
        debug_assert!(self.levels.len().is_power_of_two());
        self.levels.len().trailing_zeros()
    }

    /// Nearest-level code for `x` (binary search — levels are sorted).
    #[inline]
    pub fn encode(&self, x: f32) -> u16 {
        let lv = &self.levels;
        match lv.binary_search_by(|l| l.partial_cmp(&x).unwrap()) {
            Ok(i) => i as u16,
            Err(i) => {
                if i == 0 {
                    0
                } else if i >= lv.len() {
                    (lv.len() - 1) as u16
                } else {
                    // Tie-break toward the closer level.
                    if (x - lv[i - 1]) <= (lv[i] - x) {
                        (i - 1) as u16
                    } else {
                        i as u16
                    }
                }
            }
        }
    }

    #[inline]
    pub fn decode(&self, code: u16) -> f32 {
        self.levels[code as usize]
    }

    /// Quantize a slice in one pass; returns (codes, reconstruction).
    pub fn quantize(&self, values: &[f32]) -> (Vec<u16>, Vec<f32>) {
        let mut codes = Vec::with_capacity(values.len());
        let mut recon = Vec::with_capacity(values.len());
        for &x in values {
            let c = self.encode(x);
            codes.push(c);
            recon.push(self.decode(c));
        }
        (codes, recon)
    }

    /// Sum of squared quantization errors over `values`.
    pub fn sq_err(&self, values: &[f32]) -> f64 {
        values
            .iter()
            .map(|&x| {
                let d = (x - self.decode(self.encode(x))) as f64;
                d * d
            })
            .sum()
    }

    /// Store levels at f16 precision (what serialization does), mirroring
    /// deployment where lookup tables live in half precision.
    pub fn to_f16_precision(&self) -> Codebook {
        Codebook {
            levels: self
                .levels
                .iter()
                .map(|&x| crate::util::f16::to_f16_precision(x))
                .collect(),
        }
    }
}

/// Dense quantization result for a full matrix with per-row codebooks —
/// the common output shape for the baseline methods.
pub struct QuantizedMatrix {
    pub bits: u32,
    pub codes: Vec<u16>,
    pub row_codebooks: Vec<Codebook>,
    pub rows: usize,
    pub cols: usize,
    /// Extra storage (bits/weight) beyond codes+codebooks that the method
    /// carries (e.g. FP16 outliers, group scales); for accounting.
    pub extra_bits_per_weight: f64,
}

impl QuantizedMatrix {
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let cb = &self.row_codebooks[r];
            let row = out.row_mut(r);
            for c in 0..self.cols {
                row[c] = cb.decode(self.codes[r * self.cols + c]);
            }
        }
        out
    }

    /// Average bits/weight including per-row parameters.
    pub fn avg_bits_per_weight(&self, kind: QuantizerKind) -> f64 {
        let code_bits = self.bits as f64;
        let param_bits = kind.param_bits(self.bits) as f64 / self.cols as f64;
        code_bits + param_bits + self.extra_bits_per_weight
    }
}

/// Quantize a full matrix with one codebook per row (the paper's
/// per-output-channel granularity) using `kind`.
pub fn quantize_per_row(
    w: &Matrix,
    sens: Option<&Matrix>,
    kind: QuantizerKind,
    bits: u32,
) -> QuantizedMatrix {
    let mut codes = vec![0u16; w.numel()];
    let mut row_codebooks = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let row = w.row(r);
        let srow = sens.map(|s| s.row(r));
        let cb = kind.fit(row, srow, bits);
        for (c, &x) in row.iter().enumerate() {
            codes[r * w.cols + c] = cb.encode(x);
        }
        row_codebooks.push(cb);
    }
    QuantizedMatrix {
        bits,
        codes,
        row_codebooks,
        rows: w.rows,
        cols: w.cols,
        extra_bits_per_weight: 0.0,
    }
}

/// Per-row min/max helper.
pub fn min_max(values: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in values {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_encode_nearest() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0, 2.0]);
        assert_eq!(cb.encode(-5.0), 0);
        assert_eq!(cb.encode(0.4), 1);
        assert_eq!(cb.encode(0.6), 2);
        assert_eq!(cb.encode(10.0), 3);
        assert_eq!(cb.encode(0.5), 1); // tie → lower
        assert_eq!(cb.bits(), 2);
    }

    #[test]
    fn quantize_roundtrip_on_levels() {
        let cb = Codebook::new(vec![-2.0, -1.0, 1.0, 2.0]);
        let (codes, recon) = cb.quantize(&[-2.0, 1.0, 2.0]);
        assert_eq!(codes, vec![0, 2, 3]);
        assert_eq!(recon, vec![-2.0, 1.0, 2.0]);
        assert_eq!(cb.sq_err(&[-2.0, 1.0]), 0.0);
    }

    #[test]
    fn per_row_quantization_shapes() {
        let w = Matrix::from_vec(2, 4, vec![0.0, 1.0, 2.0, 3.0, -3.0, -2.0, -1.0, 0.0]);
        let q = quantize_per_row(&w, None, QuantizerKind::Rtn, 2);
        assert_eq!(q.row_codebooks.len(), 2);
        assert_eq!(q.codes.len(), 8);
        let deq = q.dequantize();
        assert_eq!(deq.rows, 2);
        // 2 bits over 4 distinct uniform values → exact.
        assert!(w.mse(&deq) < 1e-12);
    }

    #[test]
    fn min_max_basic() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }

    #[test]
    fn quantizer_kind_str_roundtrip() {
        for kind in [QuantizerKind::Rtn, QuantizerKind::SensitiveKmeans] {
            let s = kind.to_str();
            assert_eq!(s.parse::<QuantizerKind>().unwrap(), kind);
        }
        assert!("squeeze".parse::<QuantizerKind>().is_err());
    }
}

//! Rounding-to-nearest (RTN) uniform quantization.
//!
//! The simplest scalar quantizer: `2^n` equally-spaced levels spanning
//! `[min, max]` of the unit being quantized (asymmetric affine, matching
//! the "vanilla-RTN" baseline in Fig 3/Fig 5). ICQuant^RTN applies this
//! independently to the inlier and outlier partitions; because each
//! partition covers ≈half the range, n-bit ICQuant^RTN matches the
//! resolution of (n+1)-bit vanilla RTN (paper Fig 3).

use super::Codebook;

/// Fit a uniform codebook spanning `[min, max]` of `values`.
pub fn fit_rtn(values: &[f32], bits: u32) -> Codebook {
    let (lo, hi) = super::min_max(values);
    fit_rtn_range(lo, hi, bits)
}

/// Uniform codebook over an explicit range.
pub fn fit_rtn_range(lo: f32, hi: f32, bits: u32) -> Codebook {
    assert!(bits >= 1 && bits <= 8);
    let n = 1usize << bits;
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        // Degenerate (constant or empty input): all levels equal.
        let v = if lo.is_finite() { lo } else { 0.0 };
        return Codebook { levels: vec![v; n] };
    }
    let step = (hi - lo) / (n - 1) as f32;
    Codebook {
        levels: (0..n).map(|i| lo + step * i as f32).collect(),
    }
}

/// The paper's ICQuant^RTN outlier treatment (Appendix E.1): positive and
/// negative outliers sit on the two tails, so spend 1 bit on the sign and
/// quantize each side with an (n−1)-bit uniform codebook over its own
/// range. Returns a single 2^n-entry codebook realizing that layout.
pub fn fit_rtn_two_sided(values: &[f32], bits: u32) -> Codebook {
    assert!(bits >= 2, "two-sided RTN needs ≥2 bits");
    let neg: Vec<f32> = values.iter().copied().filter(|&x| x < 0.0).collect();
    let pos: Vec<f32> = values.iter().copied().filter(|&x| x >= 0.0).collect();
    let half = 1usize << (bits - 1);
    let mut levels = Vec::with_capacity(1 << bits);
    let side = |vals: &[f32]| -> Vec<f32> {
        if vals.is_empty() {
            return vec![0.0; half];
        }
        let (lo, hi) = super::min_max(vals);
        fit_rtn_range(lo, hi, bits - 1).levels
    };
    levels.extend(side(&neg));
    levels.extend(side(&pos));
    Codebook::new(levels)
}

/// RTN quantization error for a given range on a slice — used by the
/// clipping grid search.
pub fn rtn_sq_err(values: &[f32], lo: f32, hi: f32, bits: u32) -> f64 {
    let cb = fit_rtn_range(lo, hi, bits);
    cb.sq_err(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn levels_are_uniform_and_cover_range() {
        let cb = fit_rtn(&[-1.0, 0.2, 3.0], 3);
        assert_eq!(cb.levels.len(), 8);
        assert_eq!(cb.levels[0], -1.0);
        assert_eq!(cb.levels[7], 3.0);
        let step = cb.levels[1] - cb.levels[0];
        for w in cb.levels.windows(2) {
            assert!((w[1] - w[0] - step).abs() < 1e-6);
        }
    }

    #[test]
    fn max_error_is_half_step() {
        let (lo, hi, bits) = (-2.0f32, 2.0f32, 3u32);
        let cb = fit_rtn_range(lo, hi, bits);
        let step = (hi - lo) / 7.0;
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = lo + rng.f32() * (hi - lo);
            let err = (x - cb.decode(cb.encode(x))).abs();
            assert!(err <= step / 2.0 + 1e-6);
        }
    }

    #[test]
    fn degenerate_constant_input() {
        let cb = fit_rtn(&[5.0, 5.0, 5.0], 2);
        assert_eq!(cb.decode(cb.encode(5.0)), 5.0);
    }

    #[test]
    fn halved_range_gains_one_bit() {
        // The paper's core resolution argument (§2): halving the range at
        // n−1 bits matches the full range at n bits.
        let full = fit_rtn_range(-1.0, 1.0, 3);
        let half = fit_rtn_range(-0.5, 0.5, 2);
        let step_full = full.levels[1] - full.levels[0];
        let step_half = half.levels[1] - half.levels[0];
        // steps: 2/7 vs 1/3 — comparable resolution (within 20 %).
        assert!((step_half / step_full - 7.0 / 6.0).abs() < 0.01);
    }

    #[test]
    fn two_sided_separates_tails() {
        // Outlier values on two tails; two-sided RTN must place half the
        // levels on each side.
        let vals: Vec<f32> = vec![-3.0, -2.8, -2.5, 2.4, 2.9, 3.1];
        let cb = fit_rtn_two_sided(&vals, 3);
        assert_eq!(cb.levels.len(), 8);
        let neg = cb.levels.iter().filter(|&&x| x < 0.0).count();
        assert_eq!(neg, 4);
        // Every input lands within its own tail's range.
        for &v in &vals {
            let r = cb.decode(cb.encode(v));
            assert!((r - v).abs() < 0.35, "v={} r={}", v, r);
        }
    }

    #[test]
    fn clip_reduces_error_with_outlier() {
        // Clipping a moderate outlier shrinks error for the (large) bulk
        // by more than the clamp penalty — the premise of the clipping
        // baseline. (A single *extreme* outlier flips this: the clamp
        // penalty dominates, which is exactly why clipping underperforms
        // in the paper's comparisons.)
        let mut vals: Vec<f32> = (0..1000).map(|i| (i as f32 / 500.0) - 1.0).collect();
        vals.push(3.0);
        let full = rtn_sq_err(&vals, -1.0, 3.0, 3);
        let clipped = rtn_sq_err(&vals, -1.0, 1.0, 3);
        assert!(clipped < full, "clipped {} full {}", clipped, full);
    }
}

//! Mixed-precision baseline (SqueezeLLM-lite "dense-and-sparse"; §4.1).
//!
//! Keeps the top-γ outliers per row in FP16 (value + absolute column
//! index) and quantizes the remaining inliers with the sensitivity-aware
//! K-means quantizer. Storage overhead per outlier: 16-bit value + 16-bit
//! index = 32 bits ⇒ `32·γ` extra bits/weight — the ≈1 bit/halved-range
//! cost the paper contrasts with ICQuant's ≈0.3.

use super::{Codebook, QuantizerKind};
use crate::util::f16::to_f16_precision;
use crate::util::tensor::Matrix;

pub struct MixedPrecision {
    pub bits: u32,
    pub outlier_ratio: f64,
    pub codes: Vec<u16>,
    pub row_codebooks: Vec<Codebook>,
    /// (row, col, f16-precision value) triples for the sparse part.
    pub outliers: Vec<(u32, u32, f32)>,
    pub rows: usize,
    pub cols: usize,
    pub kind: QuantizerKind,
}

/// Split top-γ |w| per row into FP16 sparse storage; quantize the rest.
pub fn quantize_mixed(
    w: &Matrix,
    sens: Option<&Matrix>,
    kind: QuantizerKind,
    bits: u32,
    outlier_ratio: f64,
) -> MixedPrecision {
    let k = ((outlier_ratio * w.cols as f64).floor() as usize).min(w.cols);
    let mut codes = vec![0u16; w.numel()];
    let mut row_codebooks = Vec::with_capacity(w.rows);
    let mut outliers = Vec::with_capacity(w.rows * k);
    for r in 0..w.rows {
        let row = w.row(r);
        let srow = sens.map(|s| s.row(r));
        let outlier_cols = top_k_by_magnitude(row, k);
        let mut is_outlier = vec![false; w.cols];
        for &c in &outlier_cols {
            is_outlier[c] = true;
            outliers.push((r as u32, c as u32, to_f16_precision(row[c])));
        }
        let inliers: Vec<f32> =
            (0..w.cols).filter(|&c| !is_outlier[c]).map(|c| row[c]).collect();
        let inlier_sens: Option<Vec<f32>> = srow.map(|s| {
            (0..w.cols).filter(|&c| !is_outlier[c]).map(|c| s[c]).collect()
        });
        let cb = kind.fit(&inliers, inlier_sens.as_deref(), bits);
        for c in 0..w.cols {
            if !is_outlier[c] {
                codes[r * w.cols + c] = cb.encode(row[c]);
            }
        }
        row_codebooks.push(cb);
    }
    MixedPrecision {
        bits,
        outlier_ratio,
        codes,
        row_codebooks,
        outliers,
        rows: w.rows,
        cols: w.cols,
        kind,
    }
}

/// Column indices of the `k` largest |values| (ties broken by index).
pub fn top_k_by_magnitude(row: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    if k < row.len() {
        idx.select_nth_unstable_by(k, |&a, &b| {
            row[b].abs().partial_cmp(&row[a].abs()).unwrap().then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

impl MixedPrecision {
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let cb = &self.row_codebooks[r];
            for c in 0..self.cols {
                out.set(r, c, cb.decode(self.codes[r * self.cols + c]));
            }
        }
        for &(r, c, v) in &self.outliers {
            out.set(r as usize, c as usize, v);
        }
        out
    }

    /// Average bits/weight: quantized codes for everyone (the sparse format
    /// still burns a code slot) + 32 bits per outlier + codebook.
    pub fn avg_bits_per_weight(&self) -> f64 {
        let outlier_bits = 32.0 * self.outliers.len() as f64 / self.codes.len() as f64;
        self.bits as f64
            + outlier_bits
            + self.kind.param_bits(self.bits) as f64 / self.cols as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn heavy_tailed(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| {
                    if rng.bool(0.05) {
                        (rng.student_t(2.0) * 2.0) as f32
                    } else {
                        rng.normal() as f32 * 0.2
                    }
                })
                .collect(),
        )
    }

    #[test]
    fn top_k_selects_largest() {
        let row = [0.1f32, -5.0, 0.2, 3.0, -0.05];
        assert_eq!(top_k_by_magnitude(&row, 2), vec![1, 3]);
        assert_eq!(top_k_by_magnitude(&row, 0), Vec::<usize>::new());
        assert_eq!(top_k_by_magnitude(&row, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn outliers_survive_in_fp16() {
        let w = heavy_tailed(4, 256, 17);
        let q = quantize_mixed(&w, None, QuantizerKind::SensitiveKmeans, 2, 0.05);
        let d = q.dequantize();
        // Every stored outlier reconstructs to f16 precision of original.
        for &(r, c, _) in &q.outliers {
            let orig = w.get(r as usize, c as usize);
            let rec = d.get(r as usize, c as usize);
            assert!((rec - orig).abs() <= orig.abs() / 1024.0 + 1e-6);
        }
    }

    #[test]
    fn beats_plain_quantization_on_heavy_tails() {
        let w = heavy_tailed(8, 512, 23);
        let mixed = quantize_mixed(&w, None, QuantizerKind::SensitiveKmeans, 2, 0.05);
        let plain = crate::quant::quantize_per_row(&w, None, QuantizerKind::SensitiveKmeans, 2);
        assert!(w.mse(&mixed.dequantize()) < w.mse(&plain.dequantize()));
    }

    #[test]
    fn overhead_is_32_gamma() {
        let w = heavy_tailed(4, 1000, 29);
        let q = quantize_mixed(&w, None, QuantizerKind::Rtn, 2, 0.05);
        // 50 outliers/row × 32 bits / 1000 weights = 1.6, plus codes 2 and
        // RTN params 32/1000.
        assert!((q.avg_bits_per_weight() - (2.0 + 1.6 + 0.032)).abs() < 1e-9);
    }

    #[test]
    fn zero_ratio_degenerates_to_plain() {
        let w = heavy_tailed(2, 128, 31);
        let q = quantize_mixed(&w, None, QuantizerKind::Rtn, 3, 0.0);
        assert!(q.outliers.is_empty());
        let plain = crate::quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 3);
        assert!((q.dequantize().mse(&plain.dequantize())).abs() < 1e-12);
    }
}

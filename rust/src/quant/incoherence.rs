//! Incoherence processing baseline (QuIP, Chee et al. 2023; §4.1).
//!
//! Applies random orthogonal transforms on both sides of the weight
//! matrix, `W' = U W Vᵀ`, spreading outlier energy so the transformed
//! matrix is "incoherent" (entries near-Gaussian). We use the standard
//! randomized Hadamard construction `U = H·diag(±1)/√d` (QuIP#'s choice):
//! exactly orthogonal, O(d log d) to apply, and seed-reproducible so
//! inference can reapply the inverse.
//!
//! The paper's Appendix G.2 finding — rotation helps only when extreme
//! outliers exist, and is ≈neutral on already-Gaussian weights — is
//! reproduced by `icquant exp fig10`.

use crate::util::prng::Rng;
use crate::util::tensor::Matrix;

/// In-place fast Walsh–Hadamard transform (unnormalized). len must be a
/// power of two.
pub fn fwht(v: &mut [f32]) {
    let n = v.len();
    assert!(n.is_power_of_two(), "FWHT length {} not a power of two", n);
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let x = v[j];
                let y = v[j + h];
                v[j] = x + y;
                v[j + h] = x - y;
            }
            i += h * 2;
        }
        h *= 2;
    }
}

/// A seeded randomized-Hadamard orthogonal transform of dimension `d`
/// (power of two): `Q = H·D/√d`, `D = diag(±1)`.
#[derive(Clone, Debug)]
pub struct HadamardTransform {
    pub d: usize,
    signs: Vec<f32>,
}

impl HadamardTransform {
    pub fn new(d: usize, seed: u64) -> HadamardTransform {
        assert!(d.is_power_of_two());
        let mut rng = Rng::new(seed);
        let signs = (0..d).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
        HadamardTransform { d, signs }
    }

    /// y = Q x (in place).
    pub fn forward(&self, v: &mut [f32]) {
        assert_eq!(v.len(), self.d);
        for (x, s) in v.iter_mut().zip(&self.signs) {
            *x *= s;
        }
        fwht(v);
        let scale = 1.0 / (self.d as f32).sqrt();
        for x in v.iter_mut() {
            *x *= scale;
        }
    }

    /// x = Qᵀ y (in place). Since Q = H·D/√d and H is symmetric with
    /// H² = d·I: Qᵀ = D·H/√d.
    pub fn inverse(&self, v: &mut [f32]) {
        assert_eq!(v.len(), self.d);
        fwht(v);
        let scale = 1.0 / (self.d as f32).sqrt();
        for (x, s) in v.iter_mut().zip(&self.signs) {
            *x *= scale * *s;
        }
    }
}

/// Two-sided incoherence processing of a weight matrix (rows and columns
/// must be powers of two — callers pad if needed; the model dims we use
/// are already powers of two, as are Llama's).
pub struct Incoherence {
    pub row_t: HadamardTransform,
    pub col_t: HadamardTransform,
}

impl Incoherence {
    pub fn new(rows: usize, cols: usize, seed: u64) -> Incoherence {
        Incoherence {
            row_t: HadamardTransform::new(rows, seed ^ 0xA5A5),
            col_t: HadamardTransform::new(cols, seed ^ 0x5A5A),
        }
    }

    /// W' = U W Vᵀ.
    pub fn apply(&self, w: &Matrix) -> Matrix {
        let mut out = w.clone();
        // Transform each row by col_t…
        for r in 0..out.rows {
            self.col_t.forward(out.row_mut(r));
        }
        // …then each column by row_t (via transpose trick).
        let mut t = out.transpose();
        for r in 0..t.rows {
            self.row_t.forward(t.row_mut(r));
        }
        t.transpose()
    }

    /// W = Uᵀ W' V.
    pub fn invert(&self, w: &Matrix) -> Matrix {
        let mut t = w.transpose();
        for r in 0..t.rows {
            self.row_t.inverse(t.row_mut(r));
        }
        let mut out = t.transpose();
        for r in 0..out.rows {
            self.col_t.inverse(out.row_mut(r));
        }
        out
    }
}

/// Zero-pad a matrix to power-of-two dims (Hadamard needs them); the
/// companion crop undoes it. Padding with zeros is exact: the rotation
/// mixes the zeros in, and the inverse + crop restores the original
/// support.
pub fn pad_pow2(w: &Matrix) -> Matrix {
    let r = w.rows.next_power_of_two();
    let c = w.cols.next_power_of_two();
    if (r, c) == (w.rows, w.cols) {
        return w.clone();
    }
    let mut out = Matrix::zeros(r, c);
    for i in 0..w.rows {
        out.row_mut(i)[..w.cols].copy_from_slice(w.row(i));
    }
    out
}

pub fn crop(w: &Matrix, rows: usize, cols: usize) -> Matrix {
    let mut out = Matrix::zeros(rows, cols);
    for i in 0..rows {
        out.row_mut(i).copy_from_slice(&w.row(i)[..cols]);
    }
    out
}

/// QuIP-lite: incoherence-process, quantize per-row with `kind`, invert.
/// Non-power-of-two shapes are zero-padded for the transform.
pub fn quantize_incoherent(
    w: &Matrix,
    kind: super::QuantizerKind,
    bits: u32,
    seed: u64,
) -> Matrix {
    let padded = pad_pow2(w);
    let inc = Incoherence::new(padded.rows, padded.cols, seed);
    let wt = inc.apply(&padded);
    let q = super::quantize_per_row(&wt, None, kind, bits);
    crop(&inc.invert(&q.dequantize()), w.rows, w.cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn fwht_known_values() {
        let mut v = vec![1.0f32, 0.0, 0.0, 0.0];
        fwht(&mut v);
        assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0]);
        let mut v = vec![1.0f32, 1.0, 1.0, 1.0];
        fwht(&mut v);
        assert_eq!(v, vec![4.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn transform_is_orthogonal() {
        // forward then inverse is identity; norms preserved.
        let t = HadamardTransform::new(64, 42);
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mut v = orig.clone();
        let norm0: f32 = v.iter().map(|x| x * x).sum();
        t.forward(&mut v);
        let norm1: f32 = v.iter().map(|x| x * x).sum();
        assert!((norm0 - norm1).abs() / norm0 < 1e-5);
        t.inverse(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn two_sided_roundtrip() {
        let mut rng = Rng::new(2);
        let w = Matrix::from_vec(16, 32, (0..512).map(|_| rng.normal() as f32).collect());
        let inc = Incoherence::new(16, 32, 7);
        let back = inc.invert(&inc.apply(&w));
        assert!(w.mse(&back) < 1e-10);
    }

    #[test]
    fn suppresses_extreme_outlier() {
        // Appendix G.2 case 1: a single huge spike spreads out under the
        // rotation, shrinking the max |entry| dramatically.
        let mut w = Matrix::zeros(64, 64);
        for r in 0..64 {
            for c in 0..64 {
                w.set(r, c, ((r * 64 + c) as f32).sin() * 0.02);
            }
        }
        w.set(10, 20, 50.0);
        let inc = Incoherence::new(64, 64, 3);
        let wt = inc.apply(&w);
        let max0 = w.data.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let max1 = wt.data.iter().fold(0f32, |m, &x| m.max(x.abs()));
        assert!(max1 < max0 * 0.1, "max {} -> {}", max0, max1);
    }

    #[test]
    fn neutral_on_gaussian_weights() {
        // Appendix G.2 case 2: already-Gaussian weights keep ≈ the same
        // range after rotation — the paper's explanation for QuIP's small
        // gains outside the first blocks.
        let mut rng = Rng::new(4);
        let w = Matrix::from_vec(
            128,
            128,
            (0..128 * 128).map(|_| rng.normal() as f32).collect(),
        );
        let inc = Incoherence::new(128, 128, 5);
        let wt = inc.apply(&w);
        let range = |m: &Matrix| {
            let (lo, hi) = crate::quant::min_max(&m.data);
            (hi - lo) as f64
        };
        let r0 = range(&w);
        let r1 = range(&wt);
        assert!((r1 / r0 - 1.0).abs() < 0.15, "range ratio {}", r1 / r0);
    }

    #[test]
    fn quip_lite_end_to_end_better_with_spike() {
        let mut rng = Rng::new(6);
        let mut w = Matrix::from_vec(
            64,
            64,
            (0..4096).map(|_| rng.normal() as f32 * 0.02).collect(),
        );
        w.set(0, 0, 5.0);
        let rot = quantize_incoherent(&w, super::super::QuantizerKind::Rtn, 3, 11);
        let plain = super::super::quantize_per_row(&w, None, super::super::QuantizerKind::Rtn, 3)
            .dequantize();
        assert!(w.mse(&rot) < w.mse(&plain));
    }
}

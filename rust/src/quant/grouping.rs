//! Weight-grouping baseline (§1, §4.1 "Grouping").
//!
//! Divides each row into contiguous groups of size `g` and quantizes each
//! group with its own parameters, exploiting reduced local ranges. The
//! paper's §2 analysis shows this helps less than expected because
//! outliers are *uniform* — most groups still contain one. The storage
//! cost is one parameter set per group: for RTN, (scale, zero) as 2×f16 ⇒
//! `32/g` extra bits/weight; for K-means, a full table ⇒ `2^n·16/g`.

use super::{Codebook, QuantizerKind};
use crate::util::tensor::Matrix;

/// Result of grouped quantization.
pub struct GroupedQuantized {
    pub bits: u32,
    pub group_size: usize,
    pub codes: Vec<u16>,
    /// One codebook per group, row-major: `rows × ceil(cols/g)`.
    pub group_codebooks: Vec<Codebook>,
    pub rows: usize,
    pub cols: usize,
    pub kind: QuantizerKind,
}

/// Quantize with per-group codebooks.
pub fn quantize_grouped(
    w: &Matrix,
    sens: Option<&Matrix>,
    kind: QuantizerKind,
    bits: u32,
    group_size: usize,
) -> GroupedQuantized {
    assert!(group_size >= 1);
    let groups_per_row = w.cols.div_ceil(group_size);
    let mut codes = vec![0u16; w.numel()];
    let mut group_codebooks = Vec::with_capacity(w.rows * groups_per_row);
    for r in 0..w.rows {
        let row = w.row(r);
        let srow = sens.map(|s| s.row(r));
        for g in 0..groups_per_row {
            let lo = g * group_size;
            let hi = (lo + group_size).min(w.cols);
            let cb = kind.fit(&row[lo..hi], srow.map(|s| &s[lo..hi]), bits);
            for c in lo..hi {
                codes[r * w.cols + c] = cb.encode(row[c]);
            }
            group_codebooks.push(cb);
        }
    }
    GroupedQuantized {
        bits,
        group_size,
        codes,
        group_codebooks,
        rows: w.rows,
        cols: w.cols,
        kind,
    }
}

impl GroupedQuantized {
    pub fn dequantize(&self) -> Matrix {
        let groups_per_row = self.cols.div_ceil(self.group_size);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let g = c / self.group_size;
                let cb = &self.group_codebooks[r * groups_per_row + g];
                out.set(r, c, cb.decode(self.codes[r * self.cols + c]));
            }
        }
        out
    }

    /// Average bits/weight: code bits + per-group parameter amortization.
    pub fn avg_bits_per_weight(&self) -> f64 {
        self.bits as f64 + self.kind.param_bits(self.bits) as f64 / self.group_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() as f32).collect(),
        )
    }

    #[test]
    fn smaller_groups_lower_error() {
        let w = random_matrix(8, 512, 3);
        let e256 = w.mse(&quantize_grouped(&w, None, QuantizerKind::Rtn, 3, 256).dequantize());
        let e64 = w.mse(&quantize_grouped(&w, None, QuantizerKind::Rtn, 3, 64).dequantize());
        let e16 = w.mse(&quantize_grouped(&w, None, QuantizerKind::Rtn, 3, 16).dequantize());
        assert!(e64 < e256 && e16 < e64, "{} {} {}", e256, e64, e16);
    }

    #[test]
    fn overhead_accounting() {
        let w = random_matrix(2, 256, 5);
        let q = quantize_grouped(&w, None, QuantizerKind::Rtn, 3, 64);
        // RTN params 32 bits per group of 64 → 0.5 extra bits/weight.
        assert!((q.avg_bits_per_weight() - 3.5).abs() < 1e-9);
        let qk = quantize_grouped(&w, None, QuantizerKind::SensitiveKmeans, 2, 64);
        // K-means table 4×16 bits per group of 64 → 1.0 extra.
        assert!((qk.avg_bits_per_weight() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ragged_last_group() {
        let w = random_matrix(3, 100, 7); // 100 = 64 + 36
        let q = quantize_grouped(&w, None, QuantizerKind::Rtn, 2, 64);
        let d = q.dequantize();
        assert_eq!(d.cols, 100);
        // All values within the row range (sanity).
        for r in 0..3 {
            let (lo, hi) = crate::quant::min_max(w.row(r));
            for &v in d.row(r) {
                assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
            }
        }
    }

    #[test]
    fn group_of_full_row_equals_per_row() {
        let w = random_matrix(4, 128, 9);
        let grouped = quantize_grouped(&w, None, QuantizerKind::Rtn, 3, 128);
        let per_row = super::super::quantize_per_row(&w, None, QuantizerKind::Rtn, 3);
        assert!((grouped.dequantize().mse(&per_row.dequantize())).abs() < 1e-12);
    }
}

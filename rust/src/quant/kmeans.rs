//! Sensitivity-aware weighted K-means (the "SK" quantizer, SqueezeLLM
//! Kim et al. 2023; paper Appendix E.1).
//!
//! Minimizes `Σ_i s_i (w_i − c_{a(i)})²` over centroids `c` and
//! assignments `a` — the diagonal-Fisher proxy of the layer loss. In 1-D,
//! Lloyd iterations with sorted values are exact and fast: assignment
//! boundaries are midpoints between consecutive centroids.

use super::Codebook;
use crate::util::prng::Rng;

/// Fit a `2^bits`-level codebook with optional per-value sensitivities
/// (uniform if `None`). `iters` Lloyd iterations (25 is plenty in 1-D).
pub fn fit_kmeans(values: &[f32], sens: Option<&[f32]>, bits: u32, iters: usize) -> Codebook {
    let k = 1usize << bits;
    if values.is_empty() {
        return Codebook { levels: vec![0.0; k] };
    }
    if let Some(s) = sens {
        assert_eq!(s.len(), values.len());
    }

    // Sort (value, weight) — 1-D Lloyd on sorted data is O(n + k) per iter.
    let mut pairs: Vec<(f32, f32)> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| (v, sens.map_or(1.0, |s| s[i].max(1e-12))))
        .collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut centroids = init_quantile(&pairs, k);
    let mut boundaries = vec![0usize; k + 1]; // pairs[b[j]..b[j+1]] → centroid j

    for _ in 0..iters {
        // Assignment: split sorted values at centroid midpoints.
        boundaries[0] = 0;
        boundaries[k] = pairs.len();
        let mut idx = 0usize;
        for j in 1..k {
            let mid = 0.5 * (centroids[j - 1] + centroids[j]);
            while idx < pairs.len() && pairs[idx].0 <= mid {
                idx += 1;
            }
            boundaries[j] = idx;
        }
        // Update: weighted mean per segment.
        let mut moved = 0.0f32;
        for j in 0..k {
            let (lo, hi) = (boundaries[j], boundaries[j + 1]);
            if lo >= hi {
                continue; // empty cluster keeps its centroid
            }
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for &(v, w) in &pairs[lo..hi] {
                num += (v as f64) * (w as f64);
                den += w as f64;
            }
            let c = (num / den) as f32;
            moved = moved.max((c - centroids[j]).abs());
            centroids[j] = c;
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if moved < 1e-7 {
            break;
        }
    }
    Codebook::new(centroids)
}

/// Quantile-based init: robust, deterministic, and close to optimal for
/// unimodal data (better than k-means++ here and needs no RNG).
fn init_quantile(sorted_pairs: &[(f32, f32)], k: usize) -> Vec<f32> {
    let n = sorted_pairs.len();
    (0..k)
        .map(|j| {
            let q = (j as f64 + 0.5) / k as f64;
            sorted_pairs[((q * n as f64) as usize).min(n - 1)].0
        })
        .collect()
}

/// Randomized-restart variant used by the VQ module (exposed for reuse):
/// plain weighted k-means++ in 1-D with an RNG, returning the best of
/// `restarts` runs by weighted SSE. Only used for tiny k.
pub fn fit_kmeans_restarts(
    values: &[f32],
    sens: Option<&[f32]>,
    bits: u32,
    iters: usize,
    restarts: usize,
    rng: &mut Rng,
) -> Codebook {
    let mut best: Option<(f64, Codebook)> = None;
    for _ in 0..restarts.max(1) {
        // Perturb by subsampling for restart diversity.
        let cb = if restarts <= 1 || values.len() < 64 {
            fit_kmeans(values, sens, bits, iters)
        } else {
            let m = values.len() / 2 + (rng.below(values.len() as u64 / 2) as usize);
            let idx = rng.sample_indices(values.len(), m);
            let sub: Vec<f32> = idx.iter().map(|&i| values[i]).collect();
            let sub_s: Option<Vec<f32>> = sens.map(|s| idx.iter().map(|&i| s[i]).collect());
            let mut cb = fit_kmeans(&sub, sub_s.as_deref(), bits, iters);
            // Polish on full data.
            cb = polish(values, sens, cb, iters);
            cb
        };
        let err = weighted_sq_err(values, sens, &cb);
        if best.as_ref().map_or(true, |(e, _)| err < *e) {
            best = Some((err, cb));
        }
    }
    best.unwrap().1
}

fn polish(values: &[f32], sens: Option<&[f32]>, cb: Codebook, iters: usize) -> Codebook {
    // Re-run Lloyd seeded from cb's levels: implemented by running
    // fit_kmeans which re-inits by quantiles — acceptable polish proxy;
    // keep the better of the two.
    let alt = fit_kmeans(values, sens, cb.bits(), iters);
    if weighted_sq_err(values, sens, &alt) < weighted_sq_err(values, sens, &cb) {
        alt
    } else {
        cb
    }
}

/// Weighted SSE of quantizing `values` with `cb`.
pub fn weighted_sq_err(values: &[f32], sens: Option<&[f32]>, cb: &Codebook) -> f64 {
    values
        .iter()
        .enumerate()
        .map(|(i, &x)| {
            let d = (x - cb.decode(cb.encode(x))) as f64;
            sens.map_or(1.0, |s| s[i] as f64) * d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn exact_when_k_ge_distinct_values() {
        let vals = vec![-1.0f32, 0.0, 1.0, 2.0];
        let cb = fit_kmeans(&vals, None, 2, 25);
        for &v in &vals {
            assert!((cb.decode(cb.encode(v)) - v).abs() < 1e-6);
        }
    }

    #[test]
    fn beats_rtn_on_bimodal_data() {
        // K-means adapts to density; RTN wastes levels on the empty middle.
        let mut rng = Rng::new(5);
        let mut vals = Vec::new();
        for _ in 0..500 {
            vals.push(rng.normal_ms(-3.0, 0.1) as f32);
            vals.push(rng.normal_ms(3.0, 0.1) as f32);
        }
        let km = fit_kmeans(&vals, None, 2, 25);
        let rt = super::super::rtn::fit_rtn(&vals, 2);
        assert!(km.sq_err(&vals) < rt.sq_err(&vals) * 0.5);
    }

    #[test]
    fn sensitivity_pulls_centroids() {
        // Two clusters; massively upweighting one must place more levels
        // near it (lower weighted error than the unweighted fit).
        let vals: Vec<f32> = vec![0.0, 0.1, 0.2, 0.3, 10.0, 10.1, 10.2, 10.3];
        let sens: Vec<f32> = vec![100.0, 100.0, 100.0, 100.0, 0.01, 0.01, 0.01, 0.01];
        let weighted = fit_kmeans(&vals, Some(&sens), 1, 25);
        let unweighted = fit_kmeans(&vals, None, 1, 25);
        let we = weighted_sq_err(&vals, Some(&sens), &weighted);
        let ue = weighted_sq_err(&vals, Some(&sens), &unweighted);
        assert!(we <= ue + 1e-9);
        // With k=2 both levels should hug the heavy cluster... at k=1 the
        // single centroid must sit near 0.15, not the midpoint 5.15.
        assert!(weighted.levels.iter().any(|&c| c < 1.0));
    }

    #[test]
    fn monotone_in_bits() {
        let mut rng = Rng::new(9);
        let vals: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        let mut prev = f64::INFINITY;
        for bits in 1..=5 {
            let cb = fit_kmeans(&vals, None, bits, 25);
            let err = cb.sq_err(&vals);
            assert!(err < prev, "bits={} err={} prev={}", bits, err, prev);
            prev = err;
        }
    }

    #[test]
    fn gaussian_2bit_near_optimal() {
        // Lloyd-Max for N(0,1) at 4 levels: distortion ≈ 0.1175 (Max 1960).
        let mut rng = Rng::new(11);
        let vals: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        let cb = fit_kmeans(&vals, None, 2, 50);
        let mse = cb.sq_err(&vals) / vals.len() as f64;
        assert!((mse - 0.1175).abs() < 0.01, "mse={}", mse);
    }

    #[test]
    fn empty_and_constant_inputs() {
        let cb = fit_kmeans(&[], None, 2, 10);
        assert_eq!(cb.levels.len(), 4);
        let cb = fit_kmeans(&[2.5; 10], None, 2, 10);
        assert_eq!(cb.decode(cb.encode(2.5)), 2.5);
    }
}

//! Vector quantization baselines (AQLM-lite / QuIP#-lite; §4.2).
//!
//! Groups `dim` consecutive weights into vectors and quantizes each with a
//! shared k-means codebook of `2^(dim·bits)` entries — additive-codebook
//! VQ at a single level, which is AQLM's mechanism without its beam-search
//! refinement and fine-tuning. QuIP#-lite composes this with incoherence
//! processing (its Hadamard + lattice codebook pipeline at matched rate).
//!
//! Codebook sizes are capped at 4096 entries (dim·bits ≤ 12), matching
//! what's tractable for plain k-means; real AQLM's 2^16-entry codebooks
//! are noted in DESIGN.md §3 as a fidelity cap.

use super::incoherence::Incoherence;
use crate::util::prng::Rng;
use crate::util::tensor::Matrix;

/// A d-dimensional VQ codebook.
#[derive(Clone, Debug)]
pub struct VqCodebook {
    pub dim: usize,
    /// `k × dim`, row-major centroids.
    pub centroids: Vec<f32>,
}

impl VqCodebook {
    pub fn k(&self) -> usize {
        self.centroids.len() / self.dim
    }

    /// Nearest centroid (weighted L2 with optional per-coordinate scale).
    pub fn encode(&self, v: &[f32]) -> usize {
        debug_assert_eq!(v.len(), self.dim);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (i, c) in self.centroids.chunks_exact(self.dim).enumerate() {
            let mut d = 0.0f32;
            for j in 0..self.dim {
                let e = v[j] - c[j];
                d += e * e;
                if d >= best_d {
                    break;
                }
            }
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best
    }

    pub fn decode(&self, code: usize) -> &[f32] {
        &self.centroids[code * self.dim..(code + 1) * self.dim]
    }
}

/// Fit a VQ codebook with k-means (k-means++ init, `iters` Lloyd rounds)
/// on vectors drawn from `w` in groups of `dim` along rows.
pub fn fit_vq(
    w: &Matrix,
    sens: Option<&Matrix>,
    dim: usize,
    bits_per_weight: u32,
    iters: usize,
    seed: u64,
) -> VqCodebook {
    let k_bits = dim as u32 * bits_per_weight;
    assert!(k_bits <= 12, "VQ codebook 2^{} too large (cap 4096)", k_bits);
    let k = 1usize << k_bits;
    assert!(w.cols % dim == 0, "cols {} not divisible by dim {}", w.cols, dim);

    // Collect (vector, weight) training set; subsample to cap cost.
    let n_vecs = w.numel() / dim;
    let max_train = 20_000.min(n_vecs);
    let mut rng = Rng::new(seed);
    let take = if n_vecs <= max_train {
        (0..n_vecs).collect::<Vec<_>>()
    } else {
        rng.sample_indices(n_vecs, max_train)
    };
    let mut train: Vec<f32> = Vec::with_capacity(take.len() * dim);
    let mut tw: Vec<f32> = Vec::with_capacity(take.len());
    for &vi in &take {
        let start = vi * dim;
        train.extend_from_slice(&w.data[start..start + dim]);
        let swt = sens.map_or(1.0, |s| {
            s.data[start..start + dim].iter().sum::<f32>() / dim as f32
        });
        tw.push(swt.max(1e-12));
    }
    let n = tw.len();

    // k-means++ init.
    let mut centroids = vec![0.0f32; k * dim];
    let first = rng.below(n as u64) as usize;
    centroids[..dim].copy_from_slice(&train[first * dim..first * dim + dim]);
    let mut d2 = vec![f32::INFINITY; n];
    for ci in 1..k {
        // Update distances to the last placed centroid.
        let last = &centroids[(ci - 1) * dim..ci * dim];
        let mut total = 0.0f64;
        for i in 0..n {
            let v = &train[i * dim..i * dim + dim];
            let mut d = 0.0f32;
            for j in 0..dim {
                let e = v[j] - last[j];
                d += e * e;
            }
            if d < d2[i] {
                d2[i] = d;
            }
            total += (d2[i] * tw[i]) as f64;
        }
        // Sample proportional to weighted squared distance.
        let mut target = rng.f64() * total;
        let mut pick = n - 1;
        for i in 0..n {
            target -= (d2[i] * tw[i]) as f64;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids[ci * dim..(ci + 1) * dim]
            .copy_from_slice(&train[pick * dim..pick * dim + dim]);
    }

    let mut cb = VqCodebook { dim, centroids };
    // Lloyd.
    let mut sums = vec![0.0f64; k * dim];
    let mut wsum = vec![0.0f64; k];
    for _ in 0..iters {
        sums.iter_mut().for_each(|x| *x = 0.0);
        wsum.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..n {
            let v = &train[i * dim..i * dim + dim];
            let a = cb.encode(v);
            for j in 0..dim {
                sums[a * dim + j] += (v[j] * tw[i]) as f64;
            }
            wsum[a] += tw[i] as f64;
        }
        let mut moved = 0.0f32;
        for c in 0..k {
            if wsum[c] <= 0.0 {
                continue;
            }
            for j in 0..dim {
                let nc = (sums[c * dim + j] / wsum[c]) as f32;
                moved = moved.max((nc - cb.centroids[c * dim + j]).abs());
                cb.centroids[c * dim + j] = nc;
            }
        }
        if moved < 1e-6 {
            break;
        }
    }
    cb
}

/// Full-matrix VQ quantization result.
pub struct VqQuantized {
    pub dim: usize,
    pub bits_per_weight: u32,
    pub codes: Vec<u32>,
    pub codebook: VqCodebook,
    pub rows: usize,
    pub cols: usize,
}

pub fn quantize_vq(
    w: &Matrix,
    sens: Option<&Matrix>,
    dim: usize,
    bits_per_weight: u32,
    seed: u64,
) -> VqQuantized {
    let cb = fit_vq(w, sens, dim, bits_per_weight, 15, seed);
    let n_vecs = w.numel() / dim;
    let mut codes = Vec::with_capacity(n_vecs);
    for vi in 0..n_vecs {
        codes.push(cb.encode(&w.data[vi * dim..vi * dim + dim]) as u32);
    }
    VqQuantized { dim, bits_per_weight, codes, codebook: cb, rows: w.rows, cols: w.cols }
}

impl VqQuantized {
    pub fn dequantize(&self) -> Matrix {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for &c in &self.codes {
            data.extend_from_slice(self.codebook.decode(c as usize));
        }
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// bits/weight: codes + amortized shared codebook (f16 entries).
    pub fn avg_bits_per_weight(&self) -> f64 {
        let code_bits = self.bits_per_weight as f64;
        let cb_bits = (self.codebook.k() * self.dim * 16) as f64;
        code_bits + cb_bits / (self.rows * self.cols) as f64
    }
}

/// QuIP#-lite: incoherence processing + VQ. Returns the reconstruction in
/// the original basis plus the achieved bits/weight.
pub fn quantize_quip_sharp_lite(
    w: &Matrix,
    dim: usize,
    bits_per_weight: u32,
    seed: u64,
) -> (Matrix, f64) {
    use super::incoherence::{crop, pad_pow2};
    let padded = pad_pow2(w);
    let inc = Incoherence::new(padded.rows, padded.cols, seed);
    let wt = inc.apply(&padded);
    let q = quantize_vq(&wt, None, dim, bits_per_weight, seed ^ 0xF00D);
    (
        crop(&inc.invert(&q.dequantize()), w.rows, w.cols),
        q.avg_bits_per_weight(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn vq_roundtrip_shapes() {
        let w = gaussian(8, 64, 1);
        let q = quantize_vq(&w, None, 2, 2, 42);
        assert_eq!(q.codes.len(), 8 * 64 / 2);
        let d = q.dequantize();
        assert_eq!((d.rows, d.cols), (8, 64));
    }

    #[test]
    fn vq2d_beats_scalar_rtn_at_same_bits() {
        // The standard rate-distortion argument: 2-D VQ at 2 bits/weight
        // (16 centroids over pairs) beats scalar 2-bit RTN on Gaussians.
        let w = gaussian(32, 128, 3);
        let vq = quantize_vq(&w, None, 2, 2, 7);
        let rtn = crate::quant::quantize_per_row(&w, None, crate::quant::QuantizerKind::Rtn, 2);
        assert!(w.mse(&vq.dequantize()) < w.mse(&rtn.dequantize()));
    }

    #[test]
    fn encode_decode_consistent() {
        let w = gaussian(4, 32, 5);
        let cb = fit_vq(&w, None, 2, 2, 10, 9);
        for vi in 0..(w.numel() / 2) {
            let v = &w.data[vi * 2..vi * 2 + 2];
            let c = cb.encode(v);
            assert!(c < cb.k());
            // Decoded centroid must be the argmin (re-encode fixpoint).
            assert_eq!(cb.encode(cb.decode(c)), c);
        }
    }

    #[test]
    fn storage_accounting() {
        let w = gaussian(64, 64, 11);
        let q = quantize_vq(&w, None, 2, 2, 13);
        // 16 centroids × 2 dims × 16 bits = 512 bits over 4096 weights.
        assert!((q.avg_bits_per_weight() - (2.0 + 512.0 / 4096.0)).abs() < 1e-9);
    }

    #[test]
    fn quip_sharp_lite_runs_and_reconstructs() {
        let w = gaussian(32, 64, 15);
        let (rec, bits) = quantize_quip_sharp_lite(&w, 2, 2, 17);
        assert_eq!((rec.rows, rec.cols), (32, 64));
        assert!(bits >= 2.0 && bits < 3.0);
        // Error should be in a sane band for 2-bit on N(0,1).
        let mse = w.mse(&rec);
        assert!(mse > 0.0 && mse < 0.5, "mse={}", mse);
    }
}

//! GPTQ adaptive rounding (Frantar et al. 2022).
//!
//! Quantizes each row column-by-column, propagating the rounding error of
//! column `j` into the not-yet-quantized columns via the Hessian
//! `H = 2·XᵀX` of the layer's calibration activations — the "channel-wise
//! error compensation" the related-work section credits GPTQ with. Used
//! here (a) as a baseline in its own right and (b) composed with
//! incoherence processing to form the QuIP-lite baseline (QuIP =
//! incoherence + LDLQ adaptive rounding).
//!
//! Implementation follows the reference algorithm: Cholesky of
//! `H⁻¹ = (XᵀX + λI)⁻¹`, then for each column `err = (w_j − q_j)/d_jj`
//! is propagated with row `j` of the upper Cholesky factor.

use super::Codebook;
use crate::util::tensor::Matrix;

/// Dense symmetric positive-definite solve machinery (d ≤ ~2k here).
/// Returns the upper-triangular Cholesky factor U with H⁻¹ = UᵀU... we
/// follow GPTQ: compute Hinv = cholesky(inverse(H), upper=True).
fn cholesky_upper(a: &[f64], d: usize) -> Option<Vec<f64>> {
    // Standard lower Cholesky, then transpose.
    let mut l = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut sum = a[i * d + j];
            for k in 0..j {
                sum -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * d + i] = sum.sqrt();
            } else {
                l[i * d + j] = sum / l[j * d + j];
            }
        }
    }
    // Upper = Lᵀ
    let mut u = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            u[j * d + i] = l[i * d + j];
        }
    }
    Some(u)
}

/// Invert an SPD matrix via Cholesky (small d — O(d³) is fine off the hot
/// path; quantization is build-time).
fn spd_inverse(a: &[f64], d: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut sum = a[i * d + j];
            for k in 0..j {
                sum -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * d + i] = sum.sqrt();
            } else {
                l[i * d + j] = sum / l[j * d + j];
            }
        }
    }
    // Solve L Y = I, then Lᵀ X = Y  →  X = A⁻¹.
    let mut inv = vec![0.0f64; d * d];
    for col in 0..d {
        // Forward solve into y (stored in inv column).
        for i in 0..d {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                sum -= l[i * d + k] * inv[k * d + col];
            }
            inv[i * d + col] = sum / l[i * d + i];
        }
        // Backward solve with Lᵀ.
        for i in (0..d).rev() {
            let mut sum = inv[i * d + col];
            for k in i + 1..d {
                sum -= l[k * d + i] * inv[k * d + col];
            }
            inv[i * d + col] = sum / l[i * d + i];
        }
    }
    Some(inv)
}

/// Hessian proxy from calibration activations: `H = XᵀX/n + λ·mean(diag)·I`.
/// `x` is `n_samples × d_in`.
pub fn hessian_from_activations(x: &Matrix, damp: f64) -> Vec<f64> {
    let d = x.cols;
    let mut h = vec![0.0f64; d * d];
    for s in 0..x.rows {
        let row = x.row(s);
        for i in 0..d {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in i..d {
                h[i * d + j] += xi * row[j] as f64;
            }
        }
    }
    let n = x.rows.max(1) as f64;
    for i in 0..d {
        for j in i..d {
            h[i * d + j] /= n;
            h[j * d + i] = h[i * d + j];
        }
    }
    let mean_diag: f64 = (0..d).map(|i| h[i * d + i]).sum::<f64>() / d as f64;
    let lambda = damp * mean_diag.max(1e-12);
    for i in 0..d {
        h[i * d + i] += lambda;
    }
    h
}

/// GPTQ-quantize a matrix: per-row codebooks fit by `kind` on the original
/// row, adaptive rounding ordered left-to-right with error compensation.
///
/// `hessian` is the shared `d_in × d_in` proxy Hessian (row-major f64).
pub fn quantize_gptq(
    w: &Matrix,
    hessian: &[f64],
    kind: super::QuantizerKind,
    bits: u32,
) -> (Matrix, Vec<Codebook>) {
    let d = w.cols;
    assert_eq!(hessian.len(), d * d);
    let hinv = spd_inverse(hessian, d).expect("Hessian not SPD — increase damping");
    let u = cholesky_upper(&hinv, d).expect("H⁻¹ not SPD");

    let mut out = Matrix::zeros(w.rows, w.cols);
    let mut codebooks = Vec::with_capacity(w.rows);
    let mut work = vec![0.0f32; d];
    for r in 0..w.rows {
        let cb = kind.fit(w.row(r), None, bits);
        work.copy_from_slice(w.row(r));
        for j in 0..d {
            let q = cb.decode(cb.encode(work[j]));
            let djj = u[j * d + j];
            let err = (work[j] - q) as f64 / djj;
            out.set(r, j, q);
            // Propagate into remaining columns.
            for k in j + 1..d {
                work[k] -= (err * u[j * d + k]) as f32;
            }
        }
        codebooks.push(cb);
    }
    (out, codebooks)
}

/// QuIP-lite = incoherence processing + GPTQ adaptive rounding, the
/// combination Table 2 labels "QuIP". The Hessian is rotated with the
/// weights (H' = V H Vᵀ for column transform V).
pub fn quantize_quip_lite(
    w: &Matrix,
    hessian: &[f64],
    bits: u32,
    seed: u64,
) -> Matrix {
    use super::incoherence::{crop, pad_pow2, Incoherence};
    let (orig_rows, orig_cols) = (w.rows, w.cols);
    let src_d = w.cols;
    let padded = pad_pow2(w);
    let w = &padded;
    let inc = Incoherence::new(w.rows, w.cols, seed);
    let wt = inc.apply(w);
    // Rotate the Hessian: columns of W transform by col_t ⇒ H' = Q H Qᵀ.
    // Padded columns get an identity diagonal so H stays SPD.
    let d = w.cols;
    let mut hm = Matrix::zeros(d, d);
    let mean_src: f64 = (0..src_d).map(|i| hessian[i * src_d + i]).sum::<f64>()
        / src_d as f64;
    for i in 0..d {
        for j in 0..d {
            if i < src_d && j < src_d {
                hm.set(i, j, hessian[i * src_d + j] as f32);
            } else if i == j {
                hm.set(i, j, mean_src.max(1e-9) as f32);
            }
        }
    }
    // Apply col transform to rows and columns of H.
    let mut ht = hm.clone();
    for r in 0..d {
        inc.col_t.forward(ht.row_mut(r));
    }
    let mut ht = ht.transpose();
    for r in 0..d {
        inc.col_t.forward(ht.row_mut(r));
    }
    let mut h2: Vec<f64> = ht.data.iter().map(|&x| x as f64).collect();
    // Re-damp (rotation can lose SPD to fp32 roundoff).
    let mean_diag: f64 = (0..d).map(|i| h2[i * d + i]).sum::<f64>() / d as f64;
    for i in 0..d {
        h2[i * d + i] += 0.01 * mean_diag.max(1e-12);
    }
    let (qt, _) = quantize_gptq(&wt, &h2, super::QuantizerKind::Rtn, bits);
    crop(&inc.invert(&qt), orig_rows, orig_cols)
}

/// Layer-loss proxy  tr((W−Ŵ) H (W−Ŵ)ᵀ)  — what GPTQ minimizes; used to
/// verify compensation actually helps and in Fig 5(b).
pub fn hessian_loss(w: &Matrix, w_hat: &Matrix, hessian: &[f64]) -> f64 {
    let d = w.cols;
    let mut total = 0.0f64;
    let mut diff = vec![0.0f64; d];
    for r in 0..w.rows {
        let a = w.row(r);
        let b = w_hat.row(r);
        for j in 0..d {
            diff[j] = (a[j] - b[j]) as f64;
        }
        for i in 0..d {
            if diff[i] == 0.0 {
                continue;
            }
            let hrow = &hessian[i * d..(i + 1) * d];
            let mut acc = 0.0;
            for j in 0..d {
                acc += hrow[j] * diff[j];
            }
            total += diff[i] * acc;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn calib_activations(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        // Correlated activations: x = z + 0.5·shift(z) — gives GPTQ real
        // off-diagonal structure to exploit.
        let mut m = Matrix::zeros(n, d);
        for r in 0..n {
            let z: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            for c in 0..d {
                m.set(r, c, z[c] + 0.5 * z[(c + 1) % d]);
            }
        }
        m
    }

    #[test]
    fn cholesky_identity() {
        let d = 4;
        let mut a = vec![0.0f64; 16];
        for i in 0..d {
            a[i * d + i] = 1.0;
        }
        let u = cholesky_upper(&a, d).unwrap();
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((u[i * d + j] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spd_inverse_correct() {
        // A = [[4,1],[1,3]], A⁻¹ = 1/11·[[3,-1],[-1,4]]
        let a = vec![4.0, 1.0, 1.0, 3.0];
        let inv = spd_inverse(&a, 2).unwrap();
        assert!((inv[0] - 3.0 / 11.0).abs() < 1e-12);
        assert!((inv[1] + 1.0 / 11.0).abs() < 1e-12);
        assert!((inv[3] - 4.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn hessian_is_spd_and_damped() {
        let x = calib_activations(64, 16, 1);
        let h = hessian_from_activations(&x, 0.01);
        // Symmetric.
        for i in 0..16 {
            for j in 0..16 {
                assert!((h[i * 16 + j] - h[j * 16 + i]).abs() < 1e-12);
            }
        }
        // Choleskyable.
        assert!(cholesky_upper(&h, 16).is_some());
    }

    #[test]
    fn gptq_beats_plain_rtn_on_hessian_loss() {
        // The defining property: with a correlated Hessian, error
        // compensation lowers tr(ΔH Δᵀ) vs plain nearest rounding.
        let mut rng = Rng::new(3);
        let d = 64;
        let w = Matrix::from_vec(8, d, (0..8 * d).map(|_| rng.normal() as f32).collect());
        let x = calib_activations(256, d, 5);
        let h = hessian_from_activations(&x, 0.01);
        let (gptq, _) = quantize_gptq(&w, &h, super::super::QuantizerKind::Rtn, 3);
        let plain = super::super::quantize_per_row(&w, None, super::super::QuantizerKind::Rtn, 3)
            .dequantize();
        let lg = hessian_loss(&w, &gptq, &h);
        let lp = hessian_loss(&w, &plain, &h);
        assert!(lg < lp, "gptq {} !< plain {}", lg, lp);
    }

    #[test]
    fn gptq_with_identity_hessian_is_nearest_rounding() {
        let mut rng = Rng::new(7);
        let d = 32;
        let w = Matrix::from_vec(4, d, (0..4 * d).map(|_| rng.normal() as f32).collect());
        let mut h = vec![0.0f64; d * d];
        for i in 0..d {
            h[i * d + i] = 1.0;
        }
        let (gptq, _) = quantize_gptq(&w, &h, super::super::QuantizerKind::Rtn, 3);
        let plain = super::super::quantize_per_row(&w, None, super::super::QuantizerKind::Rtn, 3)
            .dequantize();
        assert!(gptq.mse(&plain) < 1e-12);
    }

    #[test]
    fn quip_lite_runs() {
        let mut rng = Rng::new(11);
        let d = 64;
        let w = Matrix::from_vec(16, d, (0..16 * d).map(|_| rng.normal() as f32 * 0.1).collect());
        let x = calib_activations(128, d, 13);
        let h = hessian_from_activations(&x, 0.01);
        let q = quantize_quip_lite(&w, &h, 2, 17);
        assert_eq!((q.rows, q.cols), (16, d));
        assert!(w.mse(&q).is_finite());
    }
}

//! Learned-clipping baseline (OmniQuant-lite; §1, Table 2 "OmniQuant-g64").
//!
//! OmniQuant learns per-group clipping thresholds by gradient descent; the
//! effect at convergence is a clip range minimizing the (weighted) squared
//! error of clipped RTN. We reproduce that fixed point directly with a
//! grid search over symmetric clip ratios per group — deterministic, and
//! matching the baseline's mechanism (shrunk range at the cost of clamped
//! outliers) without the training loop.

use super::rtn::fit_rtn_range;
use super::{Codebook, QuantizerKind};
use crate::util::tensor::Matrix;

/// Grid of clip ratios searched per group (1.0 = no clipping).
const CLIP_GRID: [f32; 12] = [
    1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2,
];

/// Find the clipped-RTN codebook minimizing SSE on `values`.
pub fn fit_clipped_rtn(values: &[f32], bits: u32) -> Codebook {
    let (lo, hi) = super::min_max(values);
    let mut best: Option<(f64, Codebook)> = None;
    for &ratio in &CLIP_GRID {
        let cb = fit_rtn_range(lo * ratio, hi * ratio, bits);
        let err = cb.sq_err(values);
        if best.as_ref().map_or(true, |(e, _)| err < *e) {
            best = Some((err, cb));
        }
    }
    best.unwrap().1
}

/// OmniQuant-lite: grouped, clip-searched RTN (the paper compares against
/// "OmniQuant-g64", i.e. group size 64).
pub struct ClippedGrouped {
    pub bits: u32,
    pub group_size: usize,
    pub codes: Vec<u16>,
    pub group_codebooks: Vec<Codebook>,
    pub rows: usize,
    pub cols: usize,
}

pub fn quantize_clipped_grouped(w: &Matrix, bits: u32, group_size: usize) -> ClippedGrouped {
    let groups_per_row = w.cols.div_ceil(group_size);
    let mut codes = vec![0u16; w.numel()];
    let mut group_codebooks = Vec::with_capacity(w.rows * groups_per_row);
    for r in 0..w.rows {
        let row = w.row(r);
        for g in 0..groups_per_row {
            let lo = g * group_size;
            let hi = (lo + group_size).min(w.cols);
            let cb = fit_clipped_rtn(&row[lo..hi], bits);
            for c in lo..hi {
                codes[r * w.cols + c] = cb.encode(row[c]);
            }
            group_codebooks.push(cb);
        }
    }
    ClippedGrouped { bits, group_size, codes, group_codebooks, rows: w.rows, cols: w.cols }
}

impl ClippedGrouped {
    pub fn dequantize(&self) -> Matrix {
        let groups_per_row = self.cols.div_ceil(self.group_size);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let cb = &self.group_codebooks[r * groups_per_row + c / self.group_size];
                out.set(r, c, cb.decode(self.codes[r * self.cols + c]));
            }
        }
        out
    }

    pub fn avg_bits_per_weight(&self) -> f64 {
        self.bits as f64
            + QuantizerKind::Rtn.param_bits(self.bits) as f64 / self.group_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn clipping_never_worse_than_plain_rtn() {
        // ratio=1.0 is in the grid, so clipped-RTN SSE ≤ plain-RTN SSE.
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let vals: Vec<f32> = (0..256)
                .map(|_| {
                    if rng.bool(0.03) {
                        rng.student_t(2.0) as f32 * 3.0
                    } else {
                        rng.normal() as f32
                    }
                })
                .collect();
            let clipped = fit_clipped_rtn(&vals, 3);
            let plain = super::super::rtn::fit_rtn(&vals, 3);
            assert!(clipped.sq_err(&vals) <= plain.sq_err(&vals) + 1e-9);
        }
    }

    #[test]
    fn clips_heavy_outlier() {
        // Large bulk + one moderate outlier: the grid search should pick a
        // clip ratio well below 1 and cut the error substantially.
        let mut vals: Vec<f32> = (0..4096).map(|i| (i as f32 - 2048.0) / 2048.0).collect();
        vals.push(8.0);
        let clipped = fit_clipped_rtn(&vals, 3);
        let plain = super::super::rtn::fit_rtn(&vals, 3);
        assert!(clipped.sq_err(&vals) < plain.sq_err(&vals) * 0.5);
        // Top level well below the outlier → it was clipped.
        assert!(*clipped.levels.last().unwrap() < 8.0);
    }

    #[test]
    fn grouped_clipped_end_to_end() {
        let mut rng = Rng::new(33);
        let w = Matrix::from_vec(
            4,
            256,
            (0..1024)
                .map(|_| {
                    if rng.bool(0.05) {
                        rng.student_t(2.5) as f32 * 2.0
                    } else {
                        rng.normal() as f32 * 0.3
                    }
                })
                .collect(),
        );
        let q = quantize_clipped_grouped(&w, 2, 64);
        let d = q.dequantize();
        assert_eq!(d.rows, 4);
        assert!((q.avg_bits_per_weight() - 2.5).abs() < 1e-9);
        // Reconstruction error is finite and better than unclipped plain RTN
        // at the same group size on this heavy-tailed data.
        let plain = crate::quant::grouping::quantize_grouped(
            &w, None, QuantizerKind::Rtn, 2, 64,
        );
        assert!(w.mse(&d) <= w.mse(&plain.dequantize()) + 1e-9);
    }
}

//! Minimal timing harness (criterion is not in the offline registry).
//!
//! [`bench_fn`] runs warmup + timed iterations and reports mean/p50/p99
//! ns/op plus optional throughput. Used by `rust/benches/*.rs`
//! (`harness = false`) and the perf pass in EXPERIMENTS.md §Perf.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional bytes processed per iteration (→ GB/s in the report).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.mean_ns)
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput_gbps() {
            Some(gbps) => format!("  {:>8.3} GB/s", gbps),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} ns/op  p50 {:>12}  p99 {:>12}{}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            tp
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.0}", ns)
    } else if ns < 1e6 {
        format!("{:.2}k", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}M", ns / 1e6)
    } else {
        format!("{:.2}G", ns / 1e9)
    }
}

/// Run `f` repeatedly; auto-calibrates iteration count to ~`budget_ms`.
pub fn bench_fn<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let target = budget_ms * 1_000_000;
    let iters = ((target / once).clamp(5, 100_000)) as usize;

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() as f64 - 1.0) * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: pct(0.5),
        p99_ns: pct(0.99),
        bytes_per_iter: None,
    }
}

/// Like [`bench_fn`] but annotates throughput.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    budget_ms: u64,
    bytes_per_iter: u64,
    f: F,
) -> BenchResult {
    let mut r = bench_fn(name, budget_ms, f);
    r.bytes_per_iter = Some(bytes_per_iter);
    r
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut acc = 0u64;
        let r = bench_fn("noop-ish", 5, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1000.0,
            p50_ns: 1000.0,
            p99_ns: 1000.0,
            bytes_per_iter: Some(2000),
        };
        // 2000 bytes / 1000 ns = 2 GB/s.
        assert!((r.throughput_gbps().unwrap() - 2.0).abs() < 1e-9);
        assert!(r.report().contains("GB/s"));
    }
}

//! Trained-model artifacts: weight manifest + flat f32 blobs produced by
//! `python/compile/train.py`, plus the Fisher sensitivity plane.
//!
//! The manifest's tensor order is the ABI shared with the AOT-lowered HLO
//! entries (`param_spec` in `python/compile/model.py`): the Rust side
//! passes weights positionally, so order is load-bearing.

use crate::util::json::Json;
use crate::util::tensor::{read_f32_at, Matrix};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Model architecture config (mirrors python `ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parse from manifest JSON (also the `ICQZ` container TOC format).
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            vocab: j.req("vocab")?.as_usize().context("vocab")?,
            d_model: j.req("d_model")?.as_usize().context("d_model")?,
            n_layers: j.req("n_layers")?.as_usize().context("n_layers")?,
            n_heads: j.req("n_heads")?.as_usize().context("n_heads")?,
            d_ff: j.req("d_ff")?.as_usize().context("d_ff")?,
            max_seq: j.req("max_seq")?.as_usize().context("max_seq")?,
        })
    }

    /// Inverse of [`Self::from_json`]; used by the `ICQZ` container TOC.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::num(self.vocab as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
        ])
    }
}

/// One named tensor: 1-D (norms) or 2-D (projections/embeddings).
#[derive(Clone, Debug)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NamedTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// View as a Matrix (2-D tensors only).
    pub fn as_matrix(&self) -> Matrix {
        assert_eq!(self.shape.len(), 2, "{} is not 2-D", self.name);
        Matrix::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    /// Is this one of the seven quantizable projections?
    pub fn is_projection(&self) -> bool {
        const SUFFIXES: [&str; 7] =
            [".wq", ".wk", ".wv", ".wo", ".w_gate", ".w_up", ".w_down"];
        SUFFIXES.iter().any(|s| self.name.ends_with(s))
    }

    /// Layer-type label for statistics tables (q_proj, ..., down_proj).
    pub fn layer_type(&self) -> Option<&'static str> {
        let map = [
            (".wq", "q_proj"),
            (".wk", "k_proj"),
            (".wv", "v_proj"),
            (".wo", "o_proj"),
            (".w_gate", "gate_proj"),
            (".w_up", "up_proj"),
            (".w_down", "down_proj"),
        ];
        map.iter()
            .find(|(s, _)| self.name.ends_with(s))
            .map(|(_, l)| *l)
    }
}

/// A loaded trained model: config + ordered tensors + sensitivity.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub config: ModelConfig,
    pub tensors: Vec<NamedTensor>,
    /// Fisher diag (same order/shapes as tensors); empty if absent.
    pub sensitivity: Vec<NamedTensor>,
    pub val_loss: f64,
    index: HashMap<String, usize>,
}

impl TrainedModel {
    /// Assemble from already-materialized tensors (the `ICQZ` container
    /// decode path — see [`crate::store::StoredModel::to_trained_model`]).
    /// Tensor order is preserved; it is the positional ABI of the
    /// AOT-compiled HLO entries.
    pub fn from_parts(
        config: ModelConfig,
        tensors: Vec<NamedTensor>,
        sensitivity: Vec<NamedTensor>,
        val_loss: f64,
    ) -> TrainedModel {
        let index = tensors
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        TrainedModel { config, tensors, sensitivity, val_loss, index }
    }

    /// Load from an artifacts directory (`model_manifest.json` +
    /// `model_weights.bin` [+ `sensitivity.bin`]).
    pub fn load(dir: &Path) -> Result<TrainedModel> {
        let manifest_path = dir.join("model_manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {}", e))?;
        let config = ModelConfig::from_json(j.req("config")?)?;
        let val_loss = j.get("val_loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);

        let weights_path = dir.join("model_weights.bin");
        let sens_path = dir.join("sensitivity.bin");
        let entries = j.req("tensors")?.as_arr().context("tensors not array")?;
        let mut tensors = Vec::with_capacity(entries.len());
        let mut sensitivity = Vec::new();
        let have_sens = sens_path.exists();
        for e in entries {
            let name = e.req("name")?.as_str().context("name")?.to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|s| s.as_usize().context("shape elem"))
                .collect::<Result<_>>()?;
            let offset = e.req("offset")?.as_usize().context("offset")?;
            let numel: usize = shape.iter().product();
            let data = read_f32_at(&weights_path, offset, numel)?;
            if have_sens {
                let sdata = read_f32_at(&sens_path, offset, numel)?;
                sensitivity.push(NamedTensor {
                    name: name.clone(),
                    shape: shape.clone(),
                    data: sdata,
                });
            }
            tensors.push(NamedTensor { name, shape, data });
        }
        let index = tensors
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        Ok(TrainedModel { config, tensors, sensitivity, val_loss, index })
    }

    pub fn get(&self, name: &str) -> Option<&NamedTensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn sensitivity_of(&self, name: &str) -> Option<&NamedTensor> {
        self.index
            .get(name)
            .and_then(|&i| self.sensitivity.get(i))
            .filter(|t| t.name == name)
    }

    /// All projection tensors (the quantization targets).
    pub fn projections(&self) -> Vec<&NamedTensor> {
        self.tensors.iter().filter(|t| t.is_projection()).collect()
    }

    /// Total projection parameters (what `bits/weight` averages over).
    pub fn projection_params(&self) -> usize {
        self.projections().iter().map(|t| t.numel()).sum()
    }

    /// Clone with some tensors' data replaced (post-quantization weights).
    pub fn with_replaced(&self, replacements: &HashMap<String, Matrix>) -> TrainedModel {
        let mut out = self.clone();
        for t in out.tensors.iter_mut() {
            if let Some(m) = replacements.get(&t.name) {
                assert_eq!(
                    (m.rows, m.cols),
                    (t.shape[0], t.shape[1]),
                    "replacement shape mismatch for {}",
                    t.name
                );
                t.data = m.data.clone();
            }
        }
        out
    }

    /// Validate tensor count/order against the python param_spec layout.
    pub fn validate(&self) -> Result<()> {
        let want = 1 + self.config.n_layers * 9 + 2;
        if self.tensors.len() != want {
            bail!("expected {} tensors, found {}", want, self.tensors.len());
        }
        if self.tensors[0].name != "tok_emb" {
            bail!("first tensor must be tok_emb");
        }
        if self.tensors.last().unwrap().name != "lm_head" {
            bail!("last tensor must be lm_head");
        }
        Ok(())
    }
}

/// Locate the artifacts directory (./artifacts in the CWD, overridable
/// with ICQ_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ICQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::write_f32_slice;

    /// Build a miniature fake artifact set on disk for IO tests.
    fn fake_artifacts(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
            "config": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 2,
                       "d_ff": 8, "max_seq": 16, "rope_theta": 10000.0,
                       "norm_eps": 1e-5},
            "val_loss": 1.5,
            "tensors": [
                {"name": "tok_emb", "shape": [8, 4], "offset": 0},
                {"name": "l0.attn_norm", "shape": [4], "offset": 32},
                {"name": "l0.wq", "shape": [4, 4], "offset": 36},
                {"name": "l0.wk", "shape": [4, 4], "offset": 52},
                {"name": "l0.wv", "shape": [4, 4], "offset": 68},
                {"name": "l0.wo", "shape": [4, 4], "offset": 84},
                {"name": "l0.mlp_norm", "shape": [4], "offset": 100},
                {"name": "l0.w_gate", "shape": [8, 4], "offset": 104},
                {"name": "l0.w_up", "shape": [8, 4], "offset": 136},
                {"name": "l0.w_down", "shape": [4, 8], "offset": 168},
                {"name": "final_norm", "shape": [4], "offset": 200},
                {"name": "lm_head", "shape": [8, 4], "offset": 204}
            ]
        }"#;
        std::fs::write(dir.join("model_manifest.json"), manifest).unwrap();
        let total = 204 + 32;
        let data: Vec<f32> = (0..total).map(|i| i as f32 * 0.01).collect();
        let mut f = std::fs::File::create(dir.join("model_weights.bin")).unwrap();
        write_f32_slice(&mut f, &data).unwrap();
        let sens: Vec<f32> = (0..total).map(|i| (i % 7) as f32).collect();
        let mut f = std::fs::File::create(dir.join("sensitivity.bin")).unwrap();
        write_f32_slice(&mut f, &sens).unwrap();
    }

    #[test]
    fn load_and_validate() {
        let dir = std::env::temp_dir().join("icq_model_test");
        fake_artifacts(&dir);
        let m = TrainedModel::load(&dir).unwrap();
        m.validate().unwrap();
        assert_eq!(m.config.d_model, 4);
        assert_eq!(m.tensors.len(), 12);
        assert_eq!(m.get("l0.wq").unwrap().shape, vec![4, 4]);
        // Offsets respected: tok_emb data starts at 0.
        assert_eq!(m.get("tok_emb").unwrap().data[1], 0.01);
        // wq at offset 36.
        assert!((m.get("l0.wq").unwrap().data[0] - 0.36).abs() < 1e-6);
        assert_eq!(m.val_loss, 1.5);
    }

    #[test]
    fn projections_and_sensitivity() {
        let dir = std::env::temp_dir().join("icq_model_test2");
        fake_artifacts(&dir);
        let m = TrainedModel::load(&dir).unwrap();
        let projs = m.projections();
        assert_eq!(projs.len(), 7);
        assert!(m.get("tok_emb").map(|t| !t.is_projection()).unwrap());
        let s = m.sensitivity_of("l0.wq").unwrap();
        assert_eq!(s.shape, vec![4, 4]);
        assert_eq!(m.get("l0.wo").unwrap().layer_type(), Some("o_proj"));
    }

    #[test]
    fn config_json_roundtrip_and_from_parts() {
        let cfg = ModelConfig {
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 2,
            d_ff: 8,
            max_seq: 16,
        };
        let back = ModelConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.vocab, cfg.vocab);
        assert_eq!(back.d_model, cfg.d_model);
        assert_eq!(back.max_seq, cfg.max_seq);
        let t = NamedTensor { name: "tok_emb".into(), shape: vec![2, 2], data: vec![0.0; 4] };
        let m = TrainedModel::from_parts(cfg, vec![t], Vec::new(), 1.0);
        assert_eq!(m.get("tok_emb").unwrap().shape, vec![2, 2]);
        assert_eq!(m.val_loss, 1.0);
    }

    #[test]
    fn with_replaced_swaps_data() {
        let dir = std::env::temp_dir().join("icq_model_test3");
        fake_artifacts(&dir);
        let m = TrainedModel::load(&dir).unwrap();
        let mut rep = HashMap::new();
        rep.insert("l0.wq".to_string(), Matrix::zeros(4, 4));
        let m2 = m.with_replaced(&rep);
        assert!(m2.get("l0.wq").unwrap().data.iter().all(|&x| x == 0.0));
        // Others untouched.
        assert_eq!(m2.get("l0.wk").unwrap().data, m.get("l0.wk").unwrap().data);
    }
}

fn main() {
    icquant::cli::run();
}

"""Synthetic byte-level corpus generator (WikiText-2/C4 stand-in).

The paper evaluates perplexity on WikiText-2 and C4; this box has neither,
so we synthesize a corpus with enough hierarchical structure (characters →
syllables → Zipf-distributed words → clause templates) that a small
transformer has something real to learn: its loss falls from ln(256) ≈ 5.5
to well under 2 bits/byte, and quantization-induced degradation behaves
like it does on natural text (DESIGN.md §2).

Run as a module to write `artifacts/corpus_{train,val,test}.bin`:

    python -m compile.corpus --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import numpy as np

VOCAB_SIZE = 256  # byte-level

_CONSONANTS = list("bcdfghjklmnprstvwz")
_VOWELS = list("aeiou")


def _make_lexicon(rng: np.random.Generator, n_words: int = 2000) -> list[str]:
    """Deterministic word list built from CV syllables."""
    syllables = [c + v for c in _CONSONANTS for v in _VOWELS]
    words = []
    seen = set()
    while len(words) < n_words:
        n_syl = int(rng.integers(1, 4))
        w = "".join(rng.choice(syllables) for _ in range(n_syl))
        if w not in seen:
            seen.add(w)
            words.append(w)
    return words


def _zipf_probs(n: int, s: float = 1.05) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** s
    return p / p.sum()


def generate_text(seed: int, n_bytes: int) -> bytes:
    """Generate ~n_bytes of structured pseudo-text."""
    rng = np.random.default_rng(seed)
    lex = _make_lexicon(rng)
    probs = _zipf_probs(len(lex))
    # Bigram flavor: each word biases the next toward a fixed successor
    # set, giving the model exploitable context beyond unigram stats.
    succ = rng.integers(0, len(lex), size=(len(lex), 16))

    out = bytearray()
    prev = int(rng.integers(0, len(lex)))
    sentence_len = 0
    while len(out) < n_bytes:
        if rng.random() < 0.7:
            idx = int(succ[prev, int(rng.integers(0, 16))])
        else:
            idx = int(rng.choice(len(lex), p=probs))
        word = lex[idx]
        if sentence_len == 0:
            word = word.capitalize()
        out.extend(word.encode("ascii"))
        sentence_len += 1
        if sentence_len >= int(rng.integers(5, 14)):
            out.extend(b". ")
            sentence_len = 0
        else:
            out.extend(b" ")
        prev = idx
    return bytes(out[:n_bytes])


def splits(seed: int = 1234, train_mb: float = 1.0):
    """Return (train, val, test) byte arrays."""
    n_train = int(train_mb * 1024 * 1024)
    train = generate_text(seed, n_bytes=n_train)
    val = generate_text(seed + 1, n_bytes=128 * 1024)
    test = generate_text(seed + 2, n_bytes=128 * 1024)
    return train, val, test


def tokens_from_bytes(data: bytes) -> np.ndarray:
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--train-mb", type=float, default=1.0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    train, val, test = splits(args.seed, args.train_mb)
    for name, blob in [("train", train), ("val", val), ("test", test)]:
        path = os.path.join(args.out_dir, f"corpus_{name}.bin")
        with open(path, "wb") as f:
            f.write(blob)
        print(f"wrote {path} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()

"""AOT lowering: JAX model variants → HLO *text* artifacts for the Rust
runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Entries lowered (shapes static per artifact; the serving router picks a
batch bucket):
  forward_loss_b{B}      (tokens, targets, *params) -> mean NLL
  token_nll_b{B}         (tokens, targets, *params) -> per-token NLL
  logits_b{B}            (tokens, *params)          -> logits
  prefill_b{B}           (tokens, *params)          -> (last_logits, k, v)
  decode_b{B}            (token, pos, k, v, *params)-> (logits, k', v')
  forward_q{bits}_b{B}   (tokens, targets, *qparams)-> mean NLL via the
                         L1 Pallas dequant-matmul kernel

`aot_manifest.json` records every entry's input/output specs — the ABI
the Rust `runtime` module loads.

Run: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    decode_step,
    forward_loss,
    forward_logits,
    forward_q_loss,
    forward_token_nll,
    param_spec,
    prefill,
    quantized_param_spec,
)

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_struct(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(shape, DTYPES[dtype])


def lower_entry(fn, arg_specs):
    args = [spec_struct(s, d) for s, d in arg_specs]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default="1,2,4,8")
    ap.add_argument("--eval-batch", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--q-bits", default="2,3,4")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = ModelConfig()
    buckets = [int(b) for b in args.buckets.split(",")]
    q_bits = [int(b) for b in args.q_bits.split(",")]
    S = cfg.max_seq
    SP = args.prefill_len
    EB = args.eval_batch

    fp_params = [(tuple(shape), "f32") for _, shape in param_spec(cfg)]
    entries = []

    def emit(name, fn, arg_specs, outputs):
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_entry(fn, arg_specs)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": [{"shape": list(s), "dtype": d} for s, d in arg_specs],
                "outputs": outputs,
            }
        )
        print(f"lowered {name} ({len(text)} chars)")

    # --- eval entries ------------------------------------------------------
    emit(
        f"forward_loss_b{EB}",
        lambda tokens, targets, *p: forward_loss(cfg, p, tokens, targets),
        [((EB, S), "i32"), ((EB, S), "i32")] + fp_params,
        [{"shape": [], "dtype": "f32"}],
    )
    emit(
        f"token_nll_b{EB}",
        lambda tokens, targets, *p: forward_token_nll(cfg, p, tokens, targets),
        [((EB, S), "i32"), ((EB, S), "i32")] + fp_params,
        [{"shape": [EB, S], "dtype": "f32"}],
    )
    emit(
        f"logits_b{EB}",
        lambda tokens, *p: forward_logits(cfg, p, tokens),
        [((EB, S), "i32")] + fp_params,
        [{"shape": [EB, S, cfg.vocab], "dtype": "f32"}],
    )

    # --- serving entries ---------------------------------------------------
    cache_shape = [cfg.n_layers, 0, cfg.n_heads, cfg.max_seq, cfg.head_dim]
    for b in buckets:
        cs = list(cache_shape)
        cs[1] = b
        emit(
            f"prefill_b{b}",
            lambda tokens, *p: prefill(cfg, p, tokens),
            [((b, SP), "i32")] + fp_params,
            [
                {"shape": [b, cfg.vocab], "dtype": "f32"},
                {"shape": cs, "dtype": "f32"},
                {"shape": cs, "dtype": "f32"},
            ],
        )
        emit(
            f"decode_b{b}",
            lambda token, pos, k, v, *p: decode_step(cfg, p, token, pos, k, v),
            [((b,), "i32"), ((), "i32"), (tuple(cs), "f32"), (tuple(cs), "f32")]
            + fp_params,
            [
                {"shape": [b, cfg.vocab], "dtype": "f32"},
                {"shape": cs, "dtype": "f32"},
                {"shape": cs, "dtype": "f32"},
            ],
        )

    # --- quantized-path entries (L1 kernel inside the graph) ---------------
    for bits in q_bits:
        qspec = quantized_param_spec(cfg, bits)
        qparams = [(tuple(shape), dt) for _, shape, dt in qspec]
        emit(
            f"forward_q{bits}_b{EB}",
            (lambda bb: lambda tokens, targets, *p: forward_q_loss(
                cfg, bb, p, tokens, targets
            ))(bits),
            [((EB, S), "i32"), ((EB, S), "i32")] + qparams,
            [{"shape": [], "dtype": "f32"}],
        )

    manifest = {
        "config": cfg.to_dict(),
        "eval_batch": EB,
        "prefill_len": SP,
        "buckets": buckets,
        "q_bits": q_bits,
        "entries": entries,
    }
    with open(os.path.join(args.out_dir, "aot_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote aot_manifest.json with {len(entries)} entries")


if __name__ == "__main__":
    main()

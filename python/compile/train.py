"""Build-time training of Llama-mini on the synthetic corpus.

Produces real trained transformer weights — the quantization target for
every perplexity experiment (DESIGN.md §2: trained weights exhibit the
Gaussian-bulk + tail structure the paper's statistics rely on) — plus the
Fisher sensitivity artifact (per-weight grad², the SqueezeLLM/ICQuant^SK
weighting) and the training loss curve.

Artifacts written to --out-dir:
  model_weights.bin    flat f32 LE, tensors in param_spec order
  model_manifest.json  config + tensor table (name/shape/offset) + metrics
  sensitivity.bin      flat f32 LE, same layout (Fisher diag)
  loss_curve.csv       step,loss

Run: python -m compile.train --steps 400 --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, forward_loss, init_params, param_spec


def batch_iterator(tokens: np.ndarray, batch: int, seq: int, seed: int):
    """Random contiguous windows; yields (inputs, targets) int32."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        x = np.stack([tokens[s : s + seq] for s in starts])
        y = np.stack([tokens[s + 1 : s + seq + 1] for s in starts])
        yield jnp.asarray(x), jnp.asarray(y)


def adam_init(params):
    return (
        [jnp.zeros_like(p) for p in params],
        [jnp.zeros_like(p) for p in params],
    )


def make_train_step(cfg: ModelConfig, lr_peak: float, total_steps: int):
    def lr_at(step):
        warm = 40.0
        warmup = jnp.minimum(step / warm, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.minimum(step / total_steps, 1.0)))
        return lr_peak * warmup * (0.1 + 0.9 * decay)

    @jax.jit
    def step_fn(params, m, v, step, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: forward_loss(cfg, p, x, y)
        )(params)
        # Global-norm clip at 1.0.
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
        scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        lr = lr_at(step)
        b1, b2, eps = 0.9, 0.95, 1e-8
        new_params, new_m, new_v = [], [], []
        t = step + 1.0
        for p, g, mi, vi in zip(params, grads, m, v):
            g = g * scale
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**t)
            vhat = vi / (1 - b2**t)
            new_params.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        # Fisher accumulator: squared raw grads.
        sq = [g * g for g in grads]
        return new_params, new_m, new_v, loss, sq

    return step_fn


def save_flat(path: str, arrays: list[np.ndarray]) -> list[int]:
    """Concatenate f32 arrays into one LE blob; return element offsets."""
    offsets = []
    off = 0
    with open(path, "wb") as f:
        for a in arrays:
            offsets.append(off)
            a32 = np.ascontiguousarray(a, dtype="<f4")
            f.write(a32.tobytes())
            off += a32.size
    return offsets


def train(
    cfg: ModelConfig,
    steps: int,
    batch: int,
    lr: float,
    seed: int,
    out_dir: str,
    fisher_steps: int = 50,
    log_every: int = 20,
):
    os.makedirs(out_dir, exist_ok=True)
    train_bytes, val_bytes, _ = corpus.splits(seed=1234)
    train_tok = corpus.tokens_from_bytes(train_bytes)
    val_tok = corpus.tokens_from_bytes(val_bytes)

    params = init_params(cfg, jax.random.PRNGKey(seed))
    m, v = adam_init(params)
    step_fn = make_train_step(cfg, lr, steps)
    it = batch_iterator(train_tok, batch, cfg.max_seq, seed + 7)

    fisher = [np.zeros(p.shape, np.float64) for p in params]
    n_fisher = 0
    curve = []
    t0 = time.time()
    for step in range(steps):
        x, y = next(it)
        params, m, v, loss, sq = step_fn(params, m, v, float(step), x, y)
        if step >= steps - fisher_steps:
            for acc, g2 in zip(fisher, sq):
                acc += np.asarray(g2, np.float64)
            n_fisher += 1
        if step % log_every == 0 or step == steps - 1:
            l = float(loss)
            curve.append((step, l))
            print(f"step {step:5d}  loss {l:.4f}  ({time.time()-t0:.1f}s)", flush=True)

    # Validation loss on fixed windows.
    eval_fn = jax.jit(lambda p, x, y: forward_loss(cfg, p, x, y))
    n_eval = 16
    se = 0.0
    for i in range(n_eval):
        s = i * (len(val_tok) - cfg.max_seq - 1) // n_eval
        x = jnp.asarray(val_tok[s : s + cfg.max_seq])[None]
        y = jnp.asarray(val_tok[s + 1 : s + cfg.max_seq + 1])[None]
        se += float(eval_fn(params, x, y))
    val_loss = se / n_eval
    print(f"val loss {val_loss:.4f}  (ppl {np.exp(val_loss):.3f})")

    # --- artifacts ---------------------------------------------------------
    np_params = [np.asarray(p) for p in params]
    offsets = save_flat(os.path.join(out_dir, "model_weights.bin"), np_params)
    fisher_np = [
        (acc / max(n_fisher, 1)).astype(np.float32) for acc in fisher
    ]
    save_flat(os.path.join(out_dir, "sensitivity.bin"), fisher_np)

    spec = param_spec(cfg)
    manifest = {
        "config": cfg.to_dict(),
        "seed": seed,
        "steps": steps,
        "batch": batch,
        "final_train_loss": curve[-1][1],
        "val_loss": val_loss,
        "val_ppl": float(np.exp(val_loss)),
        "tensors": [
            {"name": name, "shape": list(shape), "offset": off}
            for (name, shape), off in zip(spec, offsets)
        ],
    }
    with open(os.path.join(out_dir, "model_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out_dir, "loss_curve.csv"), "w") as f:
        f.write("step,loss\n")
        for s, l in curve:
            f.write(f"{s},{l}\n")
    print(f"artifacts written to {out_dir}")
    return params, val_loss


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    cfg = ModelConfig()
    train(cfg, args.steps, args.batch, args.lr, args.seed, args.out_dir)


if __name__ == "__main__":
    main()

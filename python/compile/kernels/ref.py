"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels (interpret=True) match these
references to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def dequant_ref(codes: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct W[n, k] = codebook[n, codes[n, k]].

    codes:    int32 [N, K]   fused (bits+1)-bit ICQuant runtime codes
    codebook: f32   [N, C]   per-row fused codebook (C = 2^(bits+1))
    returns:  f32   [N, K]
    """
    return jnp.take_along_axis(codebook, codes, axis=1)


def dequant_matmul_ref(
    x: jnp.ndarray, codes: jnp.ndarray, codebook: jnp.ndarray
) -> jnp.ndarray:
    """y[B, N] = x[B, K] @ dequant(codes, codebook)[N, K]^T."""
    w = dequant_ref(codes, codebook)
    return jnp.dot(x, w.T, preferred_element_type=jnp.float32)


def rtn_quant_ref(x: jnp.ndarray, lo: jnp.ndarray, step: jnp.ndarray, n_levels: int):
    """Row-wise RTN: codes = clip(round((x - lo)/step), 0, n_levels-1).

    x: f32 [N, K]; lo, step: f32 [N, 1]. Returns (codes i32, dequant f32).
    """
    codes = jnp.clip(jnp.round((x - lo) / step), 0, n_levels - 1).astype(jnp.int32)
    deq = lo + codes.astype(jnp.float32) * step
    return codes, deq

"""Row-wise RTN quantize kernel.

Build-time utility kernel: quantizes a weight tile to codes given
per-row (lo, step) affine parameters. Used to validate the Rust RTN
implementation bit-for-bit from the Python side and as the quantize half
of the pytest roundtrip suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, lo_ref, step_ref, codes_ref, deq_ref, *, n_levels: int):
    x = x_ref[...]
    lo = lo_ref[...]  # [bn, 1]
    step = step_ref[...]  # [bn, 1]
    codes = jnp.clip(jnp.round((x - lo) / step), 0, n_levels - 1).astype(jnp.int32)
    codes_ref[...] = codes
    deq_ref[...] = lo + codes.astype(jnp.float32) * step


@functools.partial(jax.jit, static_argnames=("n_levels", "bn", "bk"))
def rtn_quant(
    x: jnp.ndarray,
    lo: jnp.ndarray,
    step: jnp.ndarray,
    *,
    n_levels: int,
    bn: int = 128,
    bk: int = 256,
):
    """Quantize x[N, K] row-wise: returns (codes i32 [N,K], dequant f32).

    lo, step: f32 [N, 1] per-row affine parameters (step > 0).
    """
    n, k = x.shape
    bn = min(bn, n)
    bk = min(bk, k)
    assert n % bn == 0 and k % bk == 0, f"({n},{k}) vs blocks ({bn},{bk})"
    grid = (n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_levels=n_levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.int32),
            jax.ShapeDtypeStruct((n, k), jnp.float32),
        ],
        interpret=True,
    )(x, lo, step)

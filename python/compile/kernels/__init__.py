"""Layer-1 Pallas kernels (build-time only; lowered into HLO by aot.py).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode lowers them to plain HLO
ops that run anywhere. Real-TPU performance is *estimated* structurally
(VMEM footprint + MXU utilization of the BlockSpec schedule) in
DESIGN.md §8 — interpret-mode wallclock is not a TPU proxy.
"""

from .dequant_matmul import dequant_matmul, dequant_matmul_jnp
from .rtn_quant import rtn_quant

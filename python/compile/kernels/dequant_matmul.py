"""Fused dequantize-matmul Pallas kernel — the deployment hot-spot.

Weight-only quantization wins at inference because the weight fetch is
the bottleneck: keeping W quantized in HBM and dequantizing tile-by-tile
in VMEM turns a 16-bit stream into a ~(n+1)-bit one. The paper's CUDA
framing (per-channel codebook gather + GEMM, as in SqueezeLLM/QuIP#
kernels) maps to TPU as (DESIGN.md §8 Hardware-Adaptation):

* CUDA threadblock tile      → Pallas BlockSpec tile
* shared-memory codebook     → codebook slab resident in VMEM
* warp bit-unpack            → byte-aligned fused codes, pre-expanded at
  load by the Rust coordinator (TPU's VPU has no per-lane variable
  shift; 8-bit aligned codes trade n+1→8 bits of HBM for a gather-only
  inner loop)
* tensor-core WMMA           → MXU via jnp.dot(..., f32 accumulation)

VMEM budget at (bm, bk, bn) = (128, 128, 128), n=3:
x tile 64 KiB + codes tile 16 KiB + dequant tile 64 KiB + acc 64 KiB +
codebook slab 2^(n+1)*4*bn = 8 KiB  ⇒  ~216 KiB ≪ 16 MiB. The gather adds
bk·bn lane-ops per 2·bm·bk·bn MXU FLOPs — a 1/(2·bm) ≈ 0.4 % tax, so the
kernel stays HBM-bound and the weight-size reduction translates ≈linearly
into decode throughput, which is the paper's deployment claim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, codes_ref, cb_ref, o_ref, *, n_k_tiles: int):
    """One (bm × bn) output tile; grid axis 2 walks the K dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Gather-dequantize the weight tile in VMEM: per-row codebook lookup.
    codes = codes_ref[...]  # [bn, bk] int32
    cb = cb_ref[...]  # [bn, C]  f32
    w_tile = jnp.take_along_axis(cb, codes, axis=1)  # [bn, bk]
    # MXU-shaped contraction with f32 accumulation.
    o_ref[...] += jnp.dot(
        x_ref[...], w_tile.T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def dequant_matmul(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    codebook: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    """y[B, N] = x[B, K] @ dequant(codes, codebook)[N, K]^T.

    x        f32 [B, K]
    codes    i32 [N, K]   fused (bits+1)-bit runtime codes (byte-aligned)
    codebook f32 [N, C]   per-row fused codebook, C = 2^(bits+1)

    Block sizes are clamped to the problem size; dims must be divisible
    by the (clamped) blocks — the model dims used here are powers of two.
    """
    b, k = x.shape
    n, k2 = codes.shape
    assert k == k2, f"K mismatch: x {k} vs codes {k2}"
    c = codebook.shape[1]
    bm = min(bm, b)
    bn = min(bn, n)
    bk = min(bk, k)
    assert b % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"dims ({b},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    )
    grid = (b // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            # Codebook slab: resident across the K loop (index ignores kk).
            pl.BlockSpec((bn, c), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, codes, codebook)


def dequant_matmul_jnp(x, codes, codebook):
    """Reference path (used by the L2 model when shapes don't tile)."""
    return ref.dequant_matmul_ref(x, codes, codebook)

"""Layer-2: Llama-mini — a real Llama-architecture transformer in JAX.

RMSNorm → RoPE multi-head attention → RMSNorm → SwiGLU MLP, byte-level
vocab. All weights are *function arguments* (a flat, ordered list defined
by `param_spec`), so the Rust coordinator can feed either FP32 weights or
ICQuant-dequantized planes into the same AOT-compiled HLO.

Variants lowered by aot.py:
  * forward_loss   — mean next-token NLL over a token block (ppl eval)
  * forward_logits — full logits (scoring / zero-shot tasks)
  * prefill        — prompt pass returning last-position logits + KV cache
  * decode_step    — single-token step with KV cache (the serving path)
  * forward_q      — logits with every projection running through the L1
                     fused dequant-matmul Pallas kernel (codes+codebooks
                     as arguments): the quantized plane composing into
                     the full model inside one HLO graph.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp

from .kernels.dequant_matmul import dequant_matmul


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self):
        return asdict(self)


# The seven quantizable projections per block, in spec order. Weight
# layout is [out_features, in_features] (rows = output channels), matching
# the Rust `Matrix` convention and the paper's per-row granularity.
LINEAR_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the ABI between Python and Rust."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    spec: list[tuple[str, tuple[int, ...]]] = [("tok_emb", (v, d))]
    for i in range(cfg.n_layers):
        spec.append((f"l{i}.attn_norm", (d,)))
        spec.append((f"l{i}.wq", (d, d)))
        spec.append((f"l{i}.wk", (d, d)))
        spec.append((f"l{i}.wv", (d, d)))
        spec.append((f"l{i}.wo", (d, d)))
        spec.append((f"l{i}.mlp_norm", (d,)))
        spec.append((f"l{i}.w_gate", (ff, d)))
        spec.append((f"l{i}.w_up", (ff, d)))
        spec.append((f"l{i}.w_down", (d, ff)))
    spec.append(("final_norm", (d,)))
    spec.append(("lm_head", (v, d)))
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> list[jnp.ndarray]:
    """Glorot-style init matching the spec order."""
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-1]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


def _unflatten(cfg: ModelConfig, flat: list[jnp.ndarray]) -> dict:
    spec = param_spec(cfg)
    assert len(flat) == len(spec), f"got {len(flat)} params, want {len(spec)}"
    return {name: arr for (name, _), arr in zip(spec, flat)}


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def _rope_angles(cfg: ModelConfig, positions: jnp.ndarray) -> tuple:
    """cos/sin tables for given positions: [..., head_dim/2]."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, H, S, hd]; cos/sin: [S, hd/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(cfg, q, k, v, mask):
    """q,k,v: [B, H, S, hd]; mask: [S, T] additive."""
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale + mask
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def _split_heads(cfg, x):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(cfg, x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _block(cfg, p, i, x, cos, sin, mask, linear):
    """One transformer block; `linear(name, x2d) -> y2d` abstracts the
    matmul so the FP and quantized paths share all of this code."""
    b, s, d = x.shape

    def lin(name, t):
        t2 = t.reshape(-1, t.shape[-1])
        return linear(f"l{i}.{name}", t2).reshape(b, s, -1)

    h = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.norm_eps)
    q = _split_heads(cfg, lin("wq", h))
    k = _split_heads(cfg, lin("wk", h))
    v = _split_heads(cfg, lin("wv", h))
    q = _apply_rope(q, cos, sin)
    k = _apply_rope(k, cos, sin)
    attn = _merge_heads(cfg, _attention(cfg, q, k, v, mask))
    x = x + lin("wo", attn)

    h = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.norm_eps)
    gate = lin("w_gate", h)
    up = lin("w_up", h)
    x = x + lin("w_down", jax.nn.silu(gate) * up)
    return x


def _forward_core(cfg, p, tokens, linear):
    b, s = tokens.shape
    x = p["tok_emb"][tokens]  # [B, S, d]
    positions = jnp.arange(s)
    cos, sin = _rope_angles(cfg, positions)  # [S, hd/2]
    mask = jnp.where(
        jnp.arange(s)[:, None] >= jnp.arange(s)[None, :], 0.0, -1e9
    ).astype(jnp.float32)
    for i in range(cfg.n_layers):
        x = _block(cfg, p, i, x, cos, sin, mask, linear)
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return x @ p["lm_head"].T  # [B, S, V]


def forward_logits(cfg: ModelConfig, flat_params, tokens) -> jnp.ndarray:
    """FP path: every linear is a plain matmul on a weight argument."""
    p = _unflatten(cfg, list(flat_params))

    def linear(name, x2d):
        return x2d @ p[name].T

    return _forward_core(cfg, p, tokens, linear)


def forward_loss(cfg: ModelConfig, flat_params, tokens, targets) -> jnp.ndarray:
    """Mean next-token NLL (nats). ppl = exp(loss)."""
    logits = forward_logits(cfg, flat_params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def forward_token_nll(cfg: ModelConfig, flat_params, tokens, targets) -> jnp.ndarray:
    """Per-token NLL [B, S] — zero-shot tasks score answers with this."""
    logits = forward_logits(cfg, flat_params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# Quantized path: projections run through the L1 Pallas kernel.
# ---------------------------------------------------------------------------


def quantized_param_spec(cfg: ModelConfig, bits: int):
    """Spec for forward_q: FP tensors for embeddings/norms/lm_head, plus
    (codes, codebook) pairs for every projection."""
    c = 1 << (bits + 1)
    spec: list[tuple[str, tuple[int, ...], str]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model), "f32")
    ]
    shapes = dict(param_spec(cfg))
    for i in range(cfg.n_layers):
        spec.append((f"l{i}.attn_norm", (cfg.d_model,), "f32"))
        for name in LINEAR_NAMES[:4]:
            n, k = shapes[f"l{i}.{name}"]
            spec.append((f"l{i}.{name}.codes", (n, k), "i32"))
            spec.append((f"l{i}.{name}.cb", (n, c), "f32"))
        spec.append((f"l{i}.mlp_norm", (cfg.d_model,), "f32"))
        for name in LINEAR_NAMES[4:]:
            n, k = shapes[f"l{i}.{name}"]
            spec.append((f"l{i}.{name}.codes", (n, k), "i32"))
            spec.append((f"l{i}.{name}.cb", (n, c), "f32"))
    spec.append(("final_norm", (cfg.d_model,), "f32"))
    spec.append(("lm_head", (cfg.vocab, cfg.d_model), "f32"))
    return spec


def forward_q_logits(cfg: ModelConfig, bits: int, flat_q_params, tokens):
    """Quantized forward: weights enter the graph as ICQuant runtime codes
    + fused codebooks; the Pallas kernel dequantizes tile-wise in VMEM."""
    spec = quantized_param_spec(cfg, bits)
    assert len(flat_q_params) == len(spec)
    p = {name: arr for (name, _, _), arr in zip(spec, flat_q_params)}

    def linear(name, x2d):
        return dequant_matmul(x2d, p[f"{name}.codes"], p[f"{name}.cb"])

    return _forward_core(cfg, p, tokens, linear)


def forward_q_loss(cfg, bits, flat_q_params, tokens, targets):
    logits = forward_q_logits(cfg, bits, flat_q_params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Serving path: prefill + single-token decode with KV cache.
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, flat_params, tokens):
    """Prompt pass. tokens: [B, S_p]. Returns (last_logits [B, V],
    k_cache, v_cache [L, B, H, max_seq, hd])."""
    p = _unflatten(cfg, list(flat_params))
    b, s = tokens.shape

    def linear(name, x2d):
        return x2d @ p[name].T

    # Run the standard forward but capture K/V per layer.
    x = p["tok_emb"][tokens]
    positions = jnp.arange(s)
    cos, sin = _rope_angles(cfg, positions)
    mask = jnp.where(
        jnp.arange(s)[:, None] >= jnp.arange(s)[None, :], 0.0, -1e9
    ).astype(jnp.float32)
    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.norm_eps)

        def lin(name, t):
            t2 = t.reshape(-1, t.shape[-1])
            return (t2 @ p[f"l{i}.{name}"].T).reshape(b, s, -1)

        q = _split_heads(cfg, lin("wq", h))
        k = _split_heads(cfg, lin("wk", h))
        v = _split_heads(cfg, lin("wv", h))
        q = _apply_rope(q, cos, sin)
        k = _apply_rope(k, cos, sin)
        attn = _merge_heads(cfg, _attention(cfg, q, k, v, mask))
        x = x + lin("wo", attn)
        h = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.norm_eps)
        x = x + lin("w_down", jax.nn.silu(lin("w_gate", h)) * lin("w_up", h))

        pad = cfg.max_seq - s
        k_caches.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        v_caches.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))

    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    last_logits = x[:, -1, :] @ p["lm_head"].T
    return last_logits, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(cfg: ModelConfig, flat_params, token, pos, k_cache, v_cache):
    """One decode step. token: [B] i32; pos: scalar i32 (same position for
    the whole batch — the batcher aligns decode fronts); caches
    [L, B, H, max_seq, hd]. Returns (logits [B, V], k_cache', v_cache')."""
    p = _unflatten(cfg, list(flat_params))
    b = token.shape[0]

    x = p["tok_emb"][token][:, None, :]  # [B, 1, d]
    cos, sin = _rope_angles(cfg, pos[None])  # [1, hd/2]
    # Attend to slots 0..pos inclusive.
    slot_mask = jnp.where(
        jnp.arange(cfg.max_seq)[None, :] <= pos, 0.0, -1e9
    ).astype(jnp.float32)  # [1, max_seq]

    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.norm_eps)

        def lin(name, t):
            t2 = t.reshape(-1, t.shape[-1])
            return (t2 @ p[f"l{i}.{name}"].T).reshape(b, 1, -1)

        q = _split_heads(cfg, lin("wq", h))  # [B, H, 1, hd]
        k = _split_heads(cfg, lin("wk", h))
        v = _split_heads(cfg, lin("wv", h))
        q = _apply_rope(q, cos, sin)
        k = _apply_rope(k, cos, sin)
        kc = jax.lax.dynamic_update_slice(
            k_cache[i], k, (0, 0, pos, 0)
        )  # [B, H, max_seq, hd]
        vc = jax.lax.dynamic_update_slice(v_cache[i], v, (0, 0, pos, 0))
        attn = _attention(cfg, q, kc, vc, slot_mask)  # [B, H, 1, hd]
        x = x + lin("wo", _merge_heads(cfg, attn))
        h = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.norm_eps)
        x = x + lin("w_down", jax.nn.silu(lin("w_gate", h)) * lin("w_up", h))
        new_k.append(kc)
        new_v.append(vc)

    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    logits = x[:, 0, :] @ p["lm_head"].T
    return logits, jnp.stack(new_k), jnp.stack(new_v)

"""L2 model tests: shapes, causality, KV-cache consistency, quantized path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    ModelConfig,
    decode_step,
    forward_logits,
    forward_loss,
    forward_q_logits,
    forward_token_nll,
    init_params,
    param_spec,
    prefill,
    quantized_param_spec,
)

CFG = ModelConfig(n_layers=2, max_seq=32)  # small for test speed


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 16)).astype(np.int32))


def test_param_spec_shapes(params):
    spec = param_spec(CFG)
    assert len(spec) == 1 + CFG.n_layers * 9 + 2
    for (name, shape), p in zip(spec, params):
        assert tuple(p.shape) == shape, name


def test_logits_shape(params, tokens):
    logits = forward_logits(CFG, params, tokens)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params, tokens):
    """Changing a future token must not affect earlier logits."""
    logits0 = forward_logits(CFG, params, tokens)
    perturbed = tokens.at[:, 10].set((tokens[:, 10] + 1) % CFG.vocab)
    logits1 = forward_logits(CFG, params, perturbed)
    np.testing.assert_allclose(
        np.asarray(logits0[:, :10]), np.asarray(logits1[:, :10]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits0[:, 10:]), np.asarray(logits1[:, 10:]))


def test_loss_at_init_near_uniform(params, tokens):
    targets = jnp.roll(tokens, -1, axis=1)
    loss = float(forward_loss(CFG, params, tokens, targets))
    assert abs(loss - np.log(CFG.vocab)) < 0.5


def test_token_nll_matches_loss(params, tokens):
    targets = jnp.roll(tokens, -1, axis=1)
    per_tok = forward_token_nll(CFG, params, tokens, targets)
    assert per_tok.shape == (2, 16)
    np.testing.assert_allclose(
        float(per_tok.mean()), float(forward_loss(CFG, params, tokens, targets)),
        rtol=1e-6,
    )


def test_prefill_matches_forward(params, tokens):
    last_logits, k, v = prefill(CFG, params, tokens)
    full = forward_logits(CFG, params, tokens)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full[:, -1, :]), rtol=1e-4, atol=1e-4
    )
    assert k.shape == (CFG.n_layers, 2, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    assert v.shape == k.shape


def test_decode_steps_match_full_forward(params):
    """prefill + N decode steps must equal the full-context forward."""
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 12)).astype(np.int32))
    prompt, rest = toks[:, :8], toks[:, 8:]
    last_logits, k, v = prefill(CFG, params, prompt)
    full = forward_logits(CFG, params, toks)
    np.testing.assert_allclose(
        np.asarray(last_logits), np.asarray(full[:, 7, :]), rtol=1e-4, atol=1e-4
    )
    for i in range(rest.shape[1]):
        pos = jnp.int32(8 + i)
        logits, k, v = decode_step(CFG, params, rest[:, i], pos, k, v)
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(full[:, 8 + i, :]),
            rtol=1e-3,
            atol=1e-3,
        )


def _fake_quantize(params, bits):
    """Nearest-level quantization of every projection with a per-row
    uniform codebook — builds forward_q inputs whose dequantized values we
    can also run through the FP path."""
    spec = param_spec(CFG)
    by_name = {name: p for (name, _), p in zip(spec, params)}
    c = 1 << (bits + 1)
    qparams = []
    deq_params = []
    for name, shape in spec:
        p = by_name[name]
        is_linear = any(name.endswith(f".{l}") for l in
                        ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"))
        if not is_linear:
            continue
        lo = p.min(axis=1, keepdims=True)
        hi = p.max(axis=1, keepdims=True)
        step = jnp.maximum((hi - lo) / (c - 1), 1e-9)
        codes = jnp.clip(jnp.round((p - lo) / step), 0, c - 1).astype(jnp.int32)
        cb = lo + jnp.arange(c, dtype=jnp.float32)[None, :] * step
        by_name[f"{name}.codes"] = codes
        by_name[f"{name}.cb"] = cb
        by_name[f"{name}.deq"] = jnp.take_along_axis(cb, codes, axis=1)
    for name, _, _ in quantized_param_spec(CFG, bits):
        qparams.append(by_name[name])
    for name, _ in spec:
        deq_params.append(by_name.get(f"{name}.deq", by_name[name]))
    return qparams, deq_params


def test_forward_q_equals_fp_on_dequantized_weights(params):
    """forward_q(codes, cb) must equal forward(dequant(codes, cb)) — the
    in-graph Pallas dequant path is exactly the FP path on decoded
    weights."""
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 16)).astype(np.int32))
    bits = 2
    qparams, deq_params = _fake_quantize(params, bits)
    ql = forward_q_logits(CFG, bits, qparams, toks)
    fl = forward_logits(CFG, deq_params, toks)
    np.testing.assert_allclose(np.asarray(ql), np.asarray(fl), rtol=2e-4, atol=2e-4)

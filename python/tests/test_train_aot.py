"""Training smoke test + AOT lowering round-trip."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile.model import ModelConfig, forward_loss, init_params, param_spec
from compile.train import adam_init, batch_iterator, make_train_step, save_flat
from compile.aot import lower_entry, to_hlo_text


def test_train_step_reduces_loss():
    cfg = ModelConfig(n_layers=2, max_seq=32)
    toks = corpus.tokens_from_bytes(corpus.generate_text(1, 100_000))
    params = init_params(cfg, jax.random.PRNGKey(0))
    m, v = adam_init(params)
    step_fn = make_train_step(cfg, lr_peak=3e-3, total_steps=30)
    it = batch_iterator(toks, batch=8, seq=cfg.max_seq, seed=3)
    losses = []
    for step in range(30):
        x, y = next(it)
        params, m, v, loss, _ = step_fn(params, m, v, float(step), x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, f"{losses[0]} -> {losses[-1]}"
    assert np.isfinite(losses).all()


def test_fisher_accumulator_positive():
    cfg = ModelConfig(n_layers=1, max_seq=16)
    toks = corpus.tokens_from_bytes(corpus.generate_text(2, 50_000))
    params = init_params(cfg, jax.random.PRNGKey(1))
    m, v = adam_init(params)
    step_fn = make_train_step(cfg, 1e-3, 5)
    it = batch_iterator(toks, 4, cfg.max_seq, 5)
    x, y = next(it)
    _, _, _, _, sq = step_fn(params, m, v, 0.0, x, y)
    assert len(sq) == len(params)
    total = sum(float(jnp.sum(g)) for g in sq)
    assert total > 0


def test_save_flat_layout(tmp_path):
    arrays = [np.arange(6, dtype=np.float32).reshape(2, 3), np.ones(4, np.float32)]
    path = str(tmp_path / "w.bin")
    offsets = save_flat(path, arrays)
    assert offsets == [0, 6]
    blob = np.fromfile(path, dtype="<f4")
    np.testing.assert_array_equal(blob[:6], arrays[0].ravel())
    np.testing.assert_array_equal(blob[6:], arrays[1].ravel())


def test_hlo_text_lowering():
    """The AOT bridge: a jitted fn lowers to parseable HLO text."""
    cfg = ModelConfig(n_layers=1, max_seq=16)
    fp = [(tuple(s), "f32") for _, s in param_spec(cfg)]
    text = lower_entry(
        lambda tokens, targets, *p: forward_loss(cfg, p, tokens, targets),
        [((1, 16), "i32"), ((1, 16), "i32")] + fp,
    )
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # Parameter count matches the spec (tokens + targets + params).
    assert text.count("parameter(") >= len(fp) + 2


def test_hlo_text_small_fn():
    f = jax.jit(lambda x, y: (jnp.matmul(x, y) + 2.0,))
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(f.lower(spec, spec))
    assert "HloModule" in text and "dot" in text

"""Corpus generator tests: determinism, structure, split disjointness."""

import numpy as np

from compile import corpus


def test_deterministic():
    a = corpus.generate_text(7, 4096)
    b = corpus.generate_text(7, 4096)
    assert a == b


def test_different_seeds_differ():
    assert corpus.generate_text(1, 2048) != corpus.generate_text(2, 2048)


def test_ascii_and_size():
    text = corpus.generate_text(3, 10_000)
    assert len(text) == 10_000
    assert all(32 <= b < 127 for b in text)


def test_has_learnable_structure():
    """Bigram entropy must sit well below the uniform 8 bits/byte — the
    model needs something to learn."""
    toks = corpus.tokens_from_bytes(corpus.generate_text(11, 200_000))
    # Conditional entropy H(x_t | x_{t-1}) via bigram counts.
    counts = np.zeros((256, 256))
    np.add.at(counts, (toks[:-1], toks[1:]), 1)
    row = counts.sum(axis=1, keepdims=True)
    p = counts / np.maximum(row, 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        h = -np.nansum(p * np.log2(np.where(p > 0, p, 1)), axis=1)
    cond_entropy = float((h * (row[:, 0] / row.sum())).sum())
    assert cond_entropy < 4.5, f"bigram entropy {cond_entropy}"


def test_splits_shapes():
    train, val, test = corpus.splits(seed=99, train_mb=0.05)
    assert len(train) == int(0.05 * 1024 * 1024)
    assert len(val) == 128 * 1024 and len(test) == 128 * 1024
    assert train[:1024] != test[:1024]


def test_tokens_roundtrip():
    data = corpus.generate_text(5, 1000)
    toks = corpus.tokens_from_bytes(data)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < 256
    assert bytes(toks.astype(np.uint8).tobytes()) == data

"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes/bit-widths; assert_allclose against ref.py is
the core correctness signal for the kernels the AOT graphs embed.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dequant_matmul import dequant_matmul
from compile.kernels.rtn_quant import rtn_quant
from compile.kernels import ref


def make_inputs(rng, b, n, k, bits):
    c = 1 << (bits + 1)
    x = rng.standard_normal((b, k), dtype=np.float32)
    codes = rng.integers(0, c, size=(n, k)).astype(np.int32)
    codebook = rng.standard_normal((n, c), dtype=np.float32)
    return jnp.asarray(x), jnp.asarray(codes), jnp.asarray(codebook)


class TestDequantMatmul:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8]),
        n=st.sampled_from([8, 16, 64, 128]),
        k=st.sampled_from([8, 32, 128, 256]),
        bits=st.sampled_from([1, 2, 3, 4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, n, k, bits, seed):
        rng = np.random.default_rng(seed)
        x, codes, cb = make_inputs(rng, b, n, k, bits)
        got = dequant_matmul(x, codes, cb, bm=8, bn=8, bk=8)
        want = ref.dequant_matmul_ref(x, codes, cb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_multi_tile_k_accumulation(self):
        # K spans several tiles: accumulation across the grid's K axis.
        rng = np.random.default_rng(0)
        x, codes, cb = make_inputs(rng, 4, 16, 512, 2)
        got = dequant_matmul(x, codes, cb, bm=4, bn=8, bk=128)
        want = ref.dequant_matmul_ref(x, codes, cb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_model_shapes(self):
        # The exact shapes the L2 model uses (d=128, ff=512, B*S=512).
        rng = np.random.default_rng(1)
        for (b, n, k) in [(512, 128, 128), (512, 512, 128), (512, 128, 512)]:
            x, codes, cb = make_inputs(rng, b, n, k, 2)
            got = dequant_matmul(x, codes, cb)
            want = ref.dequant_matmul_ref(x, codes, cb)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
            )

    def test_rejects_bad_shapes(self):
        rng = np.random.default_rng(2)
        x, codes, cb = make_inputs(rng, 4, 16, 24, 2)
        with pytest.raises(AssertionError):
            dequant_matmul(x, codes, cb, bm=4, bn=16, bk=16)  # 24 % 16 != 0

    def test_codes_at_extremes(self):
        # All-zero and all-max codes exercise gather bounds.
        b, n, k, bits = 2, 8, 16, 3
        c = 1 << (bits + 1)
        x = jnp.ones((b, k), jnp.float32)
        cb = jnp.arange(n * c, dtype=jnp.float32).reshape(n, c)
        for fill in (0, c - 1):
            codes = jnp.full((n, k), fill, jnp.int32)
            got = dequant_matmul(x, codes, cb, bm=2, bn=8, bk=8)
            want = ref.dequant_matmul_ref(x, codes, cb)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestRtnQuant:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([8, 32, 128]),
        k=st.sampled_from([16, 64, 256]),
        bits=st.sampled_from([2, 3, 4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n, k, bits, seed):
        # Construct x strictly inside rounding cells (0.05..0.45 from the
        # lower level): at exact .5 ties, XLA fusion-order 1-ULP noise can
        # legitimately flip round() between the two paths.
        rng = np.random.default_rng(seed)
        levels = (1 << bits) - 1
        lo = rng.standard_normal((n, 1), dtype=np.float32)
        step = (0.1 + rng.random((n, 1), dtype=np.float32)).astype(np.float32)
        cells = rng.integers(0, levels + 1, size=(n, k)).astype(np.float32)
        frac = (0.05 + 0.4 * rng.random((n, k), dtype=np.float32)) * np.where(
            cells < levels, 1.0, -1.0
        )
        x = (lo + (cells + frac) * step).astype(np.float32)
        codes, deq = rtn_quant(
            jnp.asarray(x), jnp.asarray(lo), jnp.asarray(step),
            n_levels=1 << bits, bn=8, bk=16,
        )
        rcodes, rdeq = ref.rtn_quant_ref(
            jnp.asarray(x), jnp.asarray(lo), jnp.asarray(step), 1 << bits
        )
        np.testing.assert_array_equal(np.asarray(codes), np.asarray(rcodes))
        # atol absorbs FMA/fusion noise on near-zero reconstructions.
        np.testing.assert_allclose(
            np.asarray(deq), np.asarray(rdeq), rtol=1e-5, atol=1e-6
        )

    def test_quantization_error_bounded_by_half_step(self):
        rng = np.random.default_rng(3)
        x = rng.uniform(-1, 1, size=(16, 64)).astype(np.float32)
        lo = x.min(axis=1, keepdims=True)
        hi = x.max(axis=1, keepdims=True)
        step = ((hi - lo) / 7).astype(np.float32)
        _, deq = rtn_quant(
            jnp.asarray(x), jnp.asarray(lo), jnp.asarray(step), n_levels=8, bn=16, bk=64
        )
        err = np.abs(np.asarray(deq) - x)
        assert err.max() <= step.max() / 2 + 1e-6
